"""Great-Barrier-Reef-style demo (paper §5, reduced scale).

Reef-belt bathymetry (shelf + gaussian reef bumps), tidal forcing at the
open offshore boundary, wind stress, Coriolis, Jackett EOS and GLS
turbulence — the full physics stack of the paper's GBR case on a synthetic
mesh (the real GBR inputs are not redistributable).  Reports the
physical-to-wall-clock ratio (the paper's headline metric: 100 at full
scale on 64 GCDs) and fine-scale flow statistics (vorticity percentiles —
the paper's Fig. 20 analogue).

    PYTHONPATH=src python examples/gbr_reef.py [--steps 20] [--nx 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dg2d, geometry, mesh2d, stepper
from repro.core.extrusion import VGrid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--nx", type=int, default=24)
    ap.add_argument("--nl", type=int, default=5)
    args = ap.parse_args()

    lx, ly = 100e3, 60e3
    def open_fn(mids):          # offshore boundary at x = lx
        return mids[:, 0] > lx * (1 - 1e-9)
    m = mesh2d.rect_mesh(args.nx, args.nx * 3 // 5, lx, ly, jitter=0.2,
                         seed=5, open_edge_fn=open_fn)
    geom = geometry.geom2d_from_mesh(m)
    bf = mesh2d.reef_bathymetry(8.0, 80.0, lx, ly, n_reefs=25)
    pts = np.stack([np.asarray(geom.node_x).ravel(),
                    np.asarray(geom.node_y).ravel()], 1)
    b = jnp.asarray(bf(pts).reshape(3, m.nt).astype(np.float32))
    vg = VGrid(b=b, nl=args.nl)
    cfg = stepper.OceanConfig(nl=args.nl, dt=40.0, m_2d=20,
                              eos_kind="jackett", use_gls=True,
                              coriolis_f=-4e-5)   # southern hemisphere
    st = stepper.init_state(geom, vg, T0=24.0, S0=35.0)

    # M2-ish tide at the open boundary + steady trade wind
    def forcing_at(t):
        eta_bc = 0.8 * jnp.sin(2 * jnp.pi * t / 44712.0) * jnp.ones(
            (3, m.nt))
        return stepper.Forcing3D(
            forcing2d=dg2d.Forcing2D(eta_open=eta_bc),
            tau_x=jnp.full((3, m.nt), -5e-5),   # SE trades / rho0
            tau_y=jnp.full((3, m.nt), 3e-5),
            T_open=jnp.full((args.nl, 6, m.nt), 24.0),
            S_open=jnp.full((args.nl, 6, m.nt), 35.0))

    step = jax.jit(lambda s, f: stepper.step(geom, vg, cfg, s, f))
    print(f"mesh: {m.nt} triangles x {args.nl} layers; reef bathymetry "
          f"{float(b.min()):.0f}-{float(b.max()):.0f} m; tidal+wind forcing")
    t0 = time.time()
    for i in range(args.steps):
        st = step(st, forcing_at(st.time))
        if i % 5 == 0 or i == args.steps - 1:
            # surface vorticity (paper Fig. 20): per-element curl of u
            from repro.core.geometry import grad2d
            us = st.ux[0, 0:3, :]
            vs = st.uy[0, 0:3, :]
            vort = grad2d(geom, vs)[0] - grad2d(geom, us)[1]
            v = np.abs(np.asarray(vort))
            print(f"step {i:3d} t={float(st.time):7.0f}s "
                  f"max|u|={float(jnp.abs(st.ux).max()):.4f} m/s "
                  f"|vort| p50={np.percentile(v, 50):.2e} "
                  f"p99={np.percentile(v, 99):.2e} 1/s")
    wall = time.time() - t0
    ratio = args.steps * cfg.dt / wall
    print(f"\n{args.steps} steps in {wall:.1f}s -> physical/wall ratio "
          f"{ratio:.1f} on 1 CPU (paper: 100 at 3.3M triangles on 64 GCDs)")
    assert bool(jnp.isfinite(st.ux).all())
    print("OK")


if __name__ == "__main__":
    main()
