"""End-to-end LM training driver: the full production stack on one host.

Trains a reduced-width OLMo-family model (default ~20M params; --full_100m
for ~100M) on a synthetic token stream using the real runtime: sharded
AdamW, remat, async checkpointing, fault-tolerant runner (resume/retry/
preemption), deterministic data. Loss must decrease — the e2e validation of
the training substrate.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 300 --full_100m
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import TokenDataset
from repro.models.model import Model, count_params
from repro.optim import adamw
from repro.runtime.fault_tolerance import RunnerConfig, TrainRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full_100m", action="store_true")
    ap.add_argument("--ckpt_dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    base = get_arch("olmo-1b")
    if args.full_100m:
        arch = dataclasses.replace(base, n_layers=8, d_model=768,
                                   n_heads=12, n_kv=12, d_ff=3072,
                                   vocab=32768, remat=False)
    else:
        arch = dataclasses.replace(base, n_layers=4, d_model=384,
                                   n_heads=6, n_kv=6, d_ff=1536,
                                   vocab=8192, remat=False)
    model = Model(arch, dtype=jnp.float32)
    total, _ = count_params(model)
    print(f"model: {arch.n_layers}L d={arch.d_model} "
          f"({total / 1e6:.1f}M params)")

    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, weight_decay=0.01)
    opt = adamw.init(params)
    ds = TokenDataset(vocab=arch.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0)

    @jax.jit
    def train_step(state, batch):
        params, opt = state
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt = adamw.update(grads, opt, params, opt_cfg)
        return (params, opt), {"loss": loss}

    losses = []

    def step_fn(state, batch):
        state, metrics = train_step(state, batch)
        losses.append(float(metrics["loss"]))
        if len(losses) % 20 == 0:
            print(f"step {len(losses):4d} loss {losses[-1]:.4f} "
                  f"(avg20 {sum(losses[-20:]) / 20:.4f})", flush=True)
        return state, metrics

    runner = TrainRunner(
        step_fn, ds,
        RunnerConfig(checkpoint_dir=args.ckpt_dir, checkpoint_every=50))
    t0 = time.time()
    state = runner.run((params, opt), n_steps=args.steps, resume=True)
    wall = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / max(wall, 1e-9)
    print(f"\n{args.steps} steps in {wall:.1f}s ({tok_s:.0f} tok/s); "
          f"runner stats: {runner.stats}")
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"loss: first10 {first:.4f} -> last10 {last:.4f}")
    assert last < first, "loss did not decrease"
    print("OK: loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
