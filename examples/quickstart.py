"""Quickstart: 3D baroclinic adjustment in a closed basin.

Sets up a small unstructured basin with a temperature front, runs the full
split-IMEX 3D model (external mode bursts, implicit vertical solves, GLS
turbulence) and prints conservation/energy diagnostics every few steps.

    PYTHONPATH=src python examples/quickstart.py [--steps 30] [--nl 6]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import dg2d, geometry, mesh2d, stepper, vertical
from repro.core.extrusion import VGrid, layer_geometry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--nl", type=int, default=6)
    ap.add_argument("--nx", type=int, default=12)
    args = ap.parse_args()

    m = mesh2d.rect_mesh(args.nx, args.nx // 2, 4000.0, 2000.0, jitter=0.2,
                         seed=1)
    geom = geometry.geom2d_from_mesh(m)
    b = jnp.full((3, m.nt), 20.0)
    vg = VGrid(b=b, nl=args.nl)
    cfg = stepper.OceanConfig(nl=args.nl, dt=30.0, m_2d=10,
                              eos_kind="linear", use_gls=True,
                              coriolis_f=1e-4)
    st = stepper.init_state(geom, vg)
    # warm water on the left: the front slumps into a baroclinic circulation
    Tf = 10.0 + 4.0 * jnp.tanh((2000.0 - geom.node_x) / 400.0)
    T = jnp.broadcast_to(jnp.concatenate([Tf, Tf])[None], st.T.shape)
    st = stepper.OceanState(ext=st.ext, ux=st.ux, uy=st.uy, T=T, S=st.S,
                            turb_k=st.turb_k, turb_eps=st.turb_eps,
                            nu_t=st.nu_t, kappa_t=st.kappa_t, time=st.time)

    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
    vge0 = layer_geometry(vg, st.ext.eta)
    heat0 = float(vertical.mass_apply3d(geom, vge0.jz, st.T).sum())

    print(f"mesh: {m.nt} triangles x {args.nl} layers "
          f"({m.nt * args.nl} prisms); dt={cfg.dt}s, m={cfg.m_2d}")
    print(f"{'step':>5} {'t[s]':>7} {'max|u|':>9} {'max|eta|':>9} "
          f"{'KE':>12} {'heat drift':>11}")
    t0 = time.time()
    for i in range(args.steps):
        st = step(st)
        if i % 5 == 0 or i == args.steps - 1:
            vge = layer_geometry(vg, st.ext.eta)
            ke = float(vertical.mass_apply3d(
                geom, vge.jz, 0.5 * (st.ux ** 2 + st.uy ** 2)).sum())
            heat = float(vertical.mass_apply3d(geom, vge.jz, st.T).sum())
            print(f"{i:5d} {float(st.time):7.0f} "
                  f"{float(jnp.abs(st.ux).max()):9.5f} "
                  f"{float(jnp.abs(st.ext.eta).max()):9.5f} "
                  f"{ke:12.5e} {abs(heat - heat0) / heat0:11.2e}")
    wall = time.time() - t0
    print(f"\n{args.steps} steps in {wall:.1f}s "
          f"({wall / args.steps * 1e3:.0f} ms/step); physical/wall ratio = "
          f"{args.steps * cfg.dt / wall:.1f}")
    assert bool(jnp.isfinite(st.ux).all()), "NaN detected"
    print("OK: baroclinic circulation developed, heat conserved.")


if __name__ == "__main__":
    main()
