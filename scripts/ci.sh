#!/usr/bin/env bash
# Tier-1 CI gate: the suite must COLLECT (zero import errors — catching
# missing-optional-dependency regressions like the hypothesis one) and PASS
# on a bare jax+pytest environment, within a time budget.
#
# Usage: scripts/ci.sh [--obs-smoke|--chaos-smoke] [extra pytest args]
#   --obs-smoke   run ONLY the observability smoke: a 3-step instrumented
#                 simulation that must emit a schema-valid metrics JSONL
#                 and pass the physics monitors (exit != 0 on violation)
#   --chaos-smoke run ONLY the chaos smoke: a seeded fault matrix (NaN
#                 poisoning, corrupt checkpoint, preemption, save-thread
#                 failure) on a tiny mesh; each class must recover with a
#                 final state bitwise equal to the fault-free run
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BUDGET="${CI_TIME_BUDGET_S:-2400}"

if [[ "${1:-}" == "--obs-smoke" ]]; then
    exec timeout 600 python scripts/obs_smoke.py
fi

if [[ "${1:-}" == "--chaos-smoke" ]]; then
    exec timeout 600 python scripts/chaos_smoke.py
fi

# collection gate: any import error fails fast and loudly
timeout 300 python -m pytest -q --collect-only >/dev/null

# kernel-layer smoke: compile + run the horizontal-RHS benchmark on a tiny
# mesh (ref + fused + Pallas-interpret lateral-flux kernel) so import/shape
# regressions in the kernel layer fail fast
timeout 600 python -m benchmarks.bench_horizontal_rhs --dry-run >/dev/null

# observability smoke: instrumented 3-step run + JSONL schema validation
timeout 600 python scripts/obs_smoke.py >/dev/null

# the tier-1 command from ROADMAP.md, under the time budget
exec timeout "$BUDGET" python -m pytest -x -q "$@"
