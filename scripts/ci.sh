#!/usr/bin/env bash
# Tier-1 CI gate: the suite must COLLECT (10 modules, zero import errors —
# catching missing-optional-dependency regressions like the hypothesis one)
# and PASS on a bare jax+pytest environment, within a time budget.
#
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BUDGET="${CI_TIME_BUDGET_S:-2400}"

# collection gate: any import error fails fast and loudly
timeout 300 python -m pytest -q --collect-only >/dev/null

# kernel-layer smoke: compile + run the horizontal-RHS benchmark on a tiny
# mesh (ref + fused + Pallas-interpret lateral-flux kernel) so import/shape
# regressions in the kernel layer fail fast
timeout 600 python -m benchmarks.bench_horizontal_rhs --dry-run >/dev/null

# the tier-1 command from ROADMAP.md, under the time budget
exec timeout "$BUDGET" python -m pytest -x -q "$@"
