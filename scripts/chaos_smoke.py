"""CI chaos smoke: a seeded fault matrix on a tiny mesh, asserting recovery.

For each recoverable fault class the same standing-wave campaign runs under
a deterministic ``FaultPlan`` and must (a) complete all steps and (b) end in
a final state that is BITWISE equal (f64) to the fault-free baseline:

  * nan-poison        NaN injected into a state field mid-run; the obs
                      diagnostics localise it, the runner restores the last
                      checkpoint and re-runs
  * corrupt-ckpt      the newest checkpoint is truncated on disk before a
                      NaN failure; restore must fall back to the older
                      intact step
  * preemption        SIGTERM mid-run -> blocking checkpoint + early return;
                      a second leg resumes and finishes
  * save-thread       the async checkpoint worker raises; the error surfaces
                      at the next save and the runner retries synchronously

Exit codes: 0 ok, 1 failure.
Usage: PYTHONPATH=src python scripts/chaos_smoke.py [--steps N]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                              # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import sim_campaign                           # noqa: E402
from repro.obs import metrics as obs_metrics                    # noqa: E402
from repro.runtime import chaos                                 # noqa: E402
from repro.runtime.fault_tolerance import RunnerConfig          # noqa: E402


def _leaves(state):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(state)]


def _bitwise_equal(a, b) -> bool:
    la, lb = _leaves(a), _leaves(b)
    return len(la) == len(lb) and all(
        x.shape == y.shape and np.array_equal(x, y, equal_nan=True)
        for x, y in zip(la, lb))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    n = args.steps

    case = sim_campaign.build_case(nx=4, ny=3, nl=4)
    root = tempfile.mkdtemp(prefix="chaos_smoke_")

    def rcfg(name):
        return RunnerConfig(checkpoint_dir=os.path.join(root, name),
                            checkpoint_every=2, max_retries=3,
                            backoff_base_s=0.01, emit_metrics=False)

    def leg(name, plan, resume=False):
        return sim_campaign.run_campaign(
            case, n, rcfg(name), policy=sim_campaign.default_policy(),
            plan=plan, resume=resume)

    failures = []

    def check(name, ok, detail=""):
        status = "ok" if ok else "FAIL"
        print(f"  [{status}] {name}" + (f": {detail}" if detail else ""))
        if not ok:
            failures.append(name)

    try:
        obs_metrics.reset()
        baseline, _ = leg("baseline", plan=None)
        print(f"baseline: {n} steps, t={float(baseline.time):.1f}s")

        # --- 1. NaN poisoning -> restore + deterministic re-run -----------
        plan = chaos.FaultPlan([chaos.Fault("sim.state", "poison_nan",
                                            step=n - 1, field="T")],
                               seed=args.seed)
        st, runner = leg("nan", plan)
        check("nan-poison fired", len(plan.log) == 1)
        check("nan-poison recovered", runner.stats["retries"] == 1
              and _bitwise_equal(st, baseline),
              f"retries={runner.stats['retries']}")

        # --- 2. corrupt checkpoint -> fallback to older intact step -------
        obs_metrics.reset()
        plan = chaos.FaultPlan(
            [chaos.Fault("checkpoint.saved", "truncate", step=4),
             chaos.Fault("sim.state", "poison_nan", step=n - 1, field="ux")],
            seed=args.seed)
        st, runner = leg("corrupt", plan)
        skipped = obs_metrics.default().snapshot()["counter"].get(
            "checkpoint.corrupt_skipped", 0)
        check("corrupt-ckpt skipped corrupt step", skipped >= 1)
        check("corrupt-ckpt recovered", _bitwise_equal(st, baseline),
              f"retries={runner.stats['retries']}")

        # --- 3. preemption -> blocking save, resume leg finishes ----------
        plan = chaos.FaultPlan([chaos.Fault("runner.step", "preempt",
                                            step=n - 2)], seed=args.seed)
        st1, runner1 = leg("preempt", plan)
        check("preemption checkpointed", runner1.stats["preempted"]
              and runner1.ckpt.latest_step() is not None)
        st, runner2 = leg("preempt", plan=None, resume=True)
        check("preemption resumed bitwise", _bitwise_equal(st, baseline),
              f"resumed from step {runner1.ckpt.latest_step()}")

        # --- 4. save-thread failure -> surfaced + retried, run completes --
        plan = chaos.FaultPlan([chaos.Fault("checkpoint.write", "io_error",
                                            step=2)], seed=args.seed)
        st, runner = leg("savefail", plan)
        check("save-failure surfaced", runner.stats["ckpt_failures"] >= 1,
              f"ckpt_failures={runner.stats['ckpt_failures']}")
        check("save-failure run completed", _bitwise_equal(st, baseline))
    finally:
        shutil.rmtree(root, ignore_errors=True)

    if failures:
        print(f"FAIL chaos smoke: {failures}", file=sys.stderr)
        return 1
    print(f"OK chaos smoke: 4 fault classes recovered, final state bitwise "
          f"== baseline over {n} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
