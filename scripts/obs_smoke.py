"""CI observability smoke: a 3-step fully-instrumented simulation.

Runs the f64 standing-wave case with the flight recorder on: a JSONL
metrics sink in a run directory, host stage timers, on-device physics
diagnostics checked by a halt-mode MonitorPolicy, and a final registry
flush (kernel dispatch counters, halo counters if any, timer histograms).
Then validates the JSONL against the schema and asserts the stream covers
the three record families the flight recorder promises:

  * stage timings        (histogram "stage_time_us")
  * physics diagnostics  (diagnostics "physics", one per step)
  * kernel dispatch      (counter "kernel_dispatch")

Exit codes: 0 ok, 1 schema/coverage failure, 2 monitor violation.
Usage: PYTHONPATH=src python scripts/obs_smoke.py [--steps N] [--run-dir D]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import dg2d, geometry, mesh2d, stepper          # noqa: E402
from repro.core.extrusion import VGrid                          # noqa: E402
from repro.obs import diagnostics as obs_diag                   # noqa: E402
from repro.obs import metrics, schema, trace                    # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--run-dir", default=None)
    ap.add_argument("--trace", action="store_true",
                    help="also capture a jax.profiler trace")
    args = ap.parse_args(argv)

    run_dir = args.run_dir or trace.default_run_dir(prefix="obs")
    os.makedirs(run_dir, exist_ok=True)
    jsonl = os.path.join(run_dir, "metrics.jsonl")
    metrics.reset()
    reg = metrics.configure(jsonl)

    m = mesh2d.rect_mesh(6, 5, 2000.0, 1500.0, jitter=0.2, seed=3)
    geom = geometry.geom2d_from_mesh(m, dtype=jnp.float64)
    cfg = stepper.OceanConfig(dt=5.0, nl=4, m_2d=6)
    vg = VGrid(b=jnp.full((3, m.nt), 20.0, jnp.float64), nl=cfg.nl)
    st = stepper.init_state(geom, vg, dtype=jnp.float64)
    eta = (0.05 * jnp.cos(jnp.pi * geom.node_x / 2000.0)).astype(jnp.float64)
    st = dataclasses.replace(st, ext=dg2d.State2D(eta, st.ext.qx, st.ext.qy))

    step = jax.jit(
        lambda s: obs_diag.step_with_diagnostics(geom, vg, cfg, s))
    policy = obs_diag.MonitorPolicy(
        cfl_max=1.0, eta_max=1.0, speed_max=5.0,
        tracer_bounds={"T": (9.0, 11.0), "S": (34.0, 36.0)},
        volume_drift_max=1e-10, mass_drift_max=1e-10,
        on_violation="halt")

    try:
        with trace.trace_session(run_dir=run_dir, enabled=args.trace):
            for k in range(args.steps):
                with reg.timer("stage_time_us", stage="step"):
                    st, diag = step(st)
                    jax.block_until_ready(st)
                policy.check(diag, step=k, registry=reg)
    except obs_diag.MonitorHalt as e:
        reg.flush(step=args.steps)
        reg.close()
        print(f"FAIL monitor violation: {e}", file=sys.stderr)
        return 2
    reg.flush(step=args.steps)
    reg.close()

    n_ok, errors = schema.validate_file(jsonl)
    if errors:
        for lineno, err in errors:
            print(f"FAIL schema line {lineno}: {err}", file=sys.stderr)
        return 1
    kinds_needed = {
        "stage timings": lambda r: r["kind"] == "histogram"
        and r["name"] == "stage_time_us",
        "physics diagnostics": lambda r: r["kind"] == "diagnostics"
        and r["name"] == "physics",
        "kernel dispatch": lambda r: r["kind"] == "counter"
        and r["name"] == "kernel_dispatch",
    }
    recs = [json.loads(l) for l in open(jsonl) if l.strip()]
    missing = [k for k, pred in kinds_needed.items()
               if not any(pred(r) for r in recs)]
    n_diag = sum(1 for r in recs if r["kind"] == "diagnostics")
    if missing or n_diag < args.steps:
        print(f"FAIL coverage: missing={missing} "
              f"diagnostics={n_diag}/{args.steps}", file=sys.stderr)
        return 1
    print(f"OK {n_ok} schema-valid records in {jsonl} "
          f"({n_diag} diagnostics, {args.steps} steps)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
