"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
with hypothesis shape/dtype sweeps (per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (cell_transpose, column_solve, flash_attention,
                           matrix_free, ref, tridiag, wkv6)



def rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# --- tridiag -----------------------------------------------------------------
@settings(deadline=None, max_examples=8)
@given(nl=st.sampled_from([1, 2, 5, 16, 32]), nc=st.sampled_from([128, 256]))
def test_tridiag_sweep(nl, nc):
    rng = np.random.default_rng(nl * 1000 + nc)
    dl = rand(rng, (nl, nc)) * 0.3
    du = rand(rng, (nl, nc)) * 0.3
    d = 2.0 + jnp.abs(rand(rng, (nl, nc)))
    b = rand(rng, (nl, nc))
    out = tridiag.tridiag_cell(dl, d, du, b, interpret=True)
    exp = ref.tridiag(dl, d, du, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_tridiag_block_cols_variants():
    rng = np.random.default_rng(0)
    nl, nc = 8, 512
    dl = rand(rng, (nl, nc)) * 0.3
    du = rand(rng, (nl, nc)) * 0.3
    d = 2.0 + jnp.abs(rand(rng, (nl, nc)))
    b = rand(rng, (nl, nc))
    exp = ref.tridiag(dl, d, du, b)
    for bc in (128, 256, 512):
        out = tridiag.tridiag_cell(dl, d, du, b, block_cols=bc, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)


# --- matrix-free r/w ---------------------------------------------------------
@settings(deadline=None, max_examples=8)
@given(nl=st.sampled_from([1, 3, 8, 32]), nc=st.sampled_from([128, 256]))
def test_matrix_free_r_sweep(nl, nc):
    rng = np.random.default_rng(nl + nc)
    F = rand(rng, (nl * 6, nc))
    area = jnp.abs(rand(rng, (1, nc))) + 0.5
    rs = rand(rng, (3, nc))
    out = matrix_free.solve_r_cell(F, area, rs, interpret=True)
    exp = ref.solve_r_cell(F, area, rs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


@settings(deadline=None, max_examples=8)
@given(nl=st.sampled_from([1, 3, 8, 32]), nc=st.sampled_from([128, 256]))
def test_matrix_free_w_sweep(nl, nc):
    rng = np.random.default_rng(nl + nc + 7)
    F = rand(rng, (nl * 6, nc))
    area = jnp.abs(rand(rng, (1, nc))) + 0.5
    wf = rand(rng, (3, nc))
    out = matrix_free.solve_w_cell(F, area, wf, interpret=True)
    exp = ref.solve_w_cell(F, area, wf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_matrix_free_matches_core_solver():
    """Kernel (cell layout) == core SoA solver on a real mesh."""
    from repro.core import geometry, layout, mesh2d, vertical
    m = mesh2d.rect_mesh(8, 8, 1.0, 1.0, jitter=0.2, seed=1)  # nt=128
    geom = geometry.geom2d_from_mesh(m)
    nl = 5
    rng = np.random.default_rng(3)
    F = rand(rng, (nl, 6, m.nt))
    rs = rand(rng, (3, m.nt))
    exp = vertical.solve_r(geom, F, rs)                  # (nl, 6, nt)
    Fc = layout.soa_to_cell(F)[0]                        # (nl*6, 128)
    area_c = layout.soa2d_to_cell(geom.area[None])[0]    # (1, 128)
    rs_c = layout.soa2d_to_cell(rs)[0]                   # (3, 128)
    out_c = matrix_free.solve_r_cell(Fc, area_c, rs_c, interpret=True)
    out = layout.cell_to_soa(out_c[None], nl, 6, m.nt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-4)


# --- block-tridiagonal column solve ------------------------------------------
@settings(deadline=None, max_examples=6)
@given(nl=st.sampled_from([1, 2, 4, 8]), k=st.sampled_from([1, 2]),
       nc=st.sampled_from([128]))
def test_block_thomas_sweep(nl, k, nc):
    rng = np.random.default_rng(nl * 10 + k)
    mk = lambda: rand(rng, (nl, 6, 6, nc)) * 0.1
    lo = mk().at[0].set(0.0)
    up = mk().at[-1].set(0.0)
    dg = mk() + 2.0 * jnp.eye(6, dtype=jnp.float32)[None, :, :, None]
    b = rand(rng, (nl, 6, k, nc))
    out = column_solve.block_thomas_cell(lo, dg, up, b, interpret=True)
    exp = ref.block_thomas_cell(lo, dg, up, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-4, atol=3e-4)


def test_block_thomas_residual():
    """Solution must satisfy the system (independent of the oracle)."""
    from repro.core.vertical import Blocks, blocks_matvec
    rng = np.random.default_rng(5)
    nl, nc = 6, 128
    mk = lambda: rand(rng, (nl, 6, 6, nc)) * 0.1
    lo = mk().at[0].set(0.0)
    up = mk().at[-1].set(0.0)
    dg = mk() + 2.0 * jnp.eye(6, dtype=jnp.float32)[None, :, :, None]
    b = rand(rng, (nl, 6, 2, nc))
    x = column_solve.block_thomas_cell(lo, dg, up, b, interpret=True)
    xk = jnp.moveaxis(x, 2, 0)
    resid = jnp.stack([blocks_matvec(Blocks(lo, dg, up), xk[i])
                       for i in range(2)]) - jnp.moveaxis(b, 2, 0)
    assert float(jnp.abs(resid).max()) < 1e-3


# --- cell transpose ----------------------------------------------------------
@settings(deadline=None, max_examples=6)
@given(nl=st.sampled_from([1, 4, 16]), nc=st.sampled_from([1, 2, 5]))
def test_cell_transpose_roundtrip(nl, nc):
    nt = nc * 128
    x = jnp.arange(nl * 6 * nt, dtype=jnp.float32).reshape(nl, 6, nt)
    c = cell_transpose.soa_to_cell(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(ref.soa_to_cell(x)))
    back = cell_transpose.cell_to_soa(c, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# --- wkv6 ---------------------------------------------------------------------
@settings(deadline=None, max_examples=6)
@given(bh=st.sampled_from([1, 3]), t=st.sampled_from([128, 256]),
       kd=st.sampled_from([16, 64]))
def test_wkv6_sweep(bh, t, kd):
    rng = np.random.default_rng(bh * t + kd)
    r = rand(rng, (bh, t, kd)) * 0.5
    k = rand(rng, (bh, t, kd)) * 0.5
    v = rand(rng, (bh, t, kd)) * 0.5
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(bh, t, kd)) * 0.5 - 1.0))
                    ).astype(jnp.float32)  # decay in (0, 1)
    u = rand(rng, (kd,)) * 0.5
    out = wkv6.wkv6(r, k, v, w, u, t_block=128, interpret=True)
    exp = ref.wkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_state_carries_across_blocks():
    """Multi-block T must equal single-block T (state persists in scratch)."""
    rng = np.random.default_rng(9)
    r = rand(rng, (2, 256, 32)) * 0.5
    k = rand(rng, (2, 256, 32)) * 0.5
    v = rand(rng, (2, 256, 32)) * 0.5
    w = jnp.full((2, 256, 32), 0.9, jnp.float32)
    u = rand(rng, (32,))
    a = wkv6.wkv6(r, k, v, w, u, t_block=128, interpret=True)
    b = wkv6.wkv6(r, k, v, w, u, t_block=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# --- flash attention ----------------------------------------------------------
@settings(deadline=None, max_examples=6)
@given(t=st.sampled_from([128, 256]), d=st.sampled_from([32, 64]),
       causal=st.booleans())
def test_flash_attention_sweep(t, d, causal):
    rng = np.random.default_rng(t + d)
    q = rand(rng, (2, t, d)) * 0.3
    k = rand(rng, (2, t, d)) * 0.3
    v = rand(rng, (2, t, d)) * 0.3
    out = flash_attention.flash_attention(q, k, v, causal=causal,
                                          interpret=True)
    exp = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_window_softcap():
    rng = np.random.default_rng(11)
    q = rand(rng, (1, 256, 32)) * 0.5
    k = rand(rng, (1, 256, 32)) * 0.5
    v = rand(rng, (1, 256, 32)) * 0.5
    out = flash_attention.flash_attention(q, k, v, causal=True, window=64,
                                          softcap=30.0, interpret=True)
    exp = ref.attention(q, k, v, causal=True, window=64, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_dense():
    """The XLA fallback (used in the dry-run) must match dense attention."""
    rng = np.random.default_rng(13)
    q = rand(rng, (2, 128, 32)) * 0.5
    k = rand(rng, (2, 512, 32)) * 0.5
    v = rand(rng, (2, 512, 32)) * 0.5
    out = ref.chunked_attention(q, k, v, causal=False, chunk=128)
    exp = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


# --- custom-VJP flash attention (models/attention.py) --------------------------
def test_flash_xla_forward_matches_dense():
    from repro.models.attention import flash_attention_xla
    rng = np.random.default_rng(21)
    B, H, T, d = 2, 3, 256, 32
    q = jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32)) * 0.5
    for causal, window, cap in [(True, None, None), (False, None, None),
                                (True, 64, None), (True, None, 30.0)]:
        out = flash_attention_xla(q, k, v, causal, window, cap, 64, 128)
        exp = ref.attention(q.reshape(B * H, T, d), k.reshape(B * H, T, d),
                            v.reshape(B * H, T, d), causal=causal,
                            window=window, softcap=cap).reshape(B, H, T, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)


def test_flash_xla_gradient_matches_dense():
    """The custom VJP must match autodiff through the dense reference."""
    from repro.models.attention import flash_attention_xla
    rng = np.random.default_rng(22)
    B, H, T, d = 1, 2, 128, 16
    q = jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32)) * 0.5
    co = jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32))

    for causal, window, cap in [(True, None, None), (True, 32, None),
                                (True, None, 20.0), (False, None, None)]:
        def f_flash(q, k, v):
            return (flash_attention_xla(q, k, v, causal, window, cap,
                                        32, 64) * co).sum()

        def f_dense(q, k, v):
            out = ref.attention(q.reshape(B * H, T, d),
                                k.reshape(B * H, T, d),
                                v.reshape(B * H, T, d), causal=causal,
                                window=window, softcap=cap)
            return (out.reshape(B, H, T, d) * co).sum()

        g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-3)


def test_wkv_chunked_matches_sequential():
    """Chunkwise-parallel WKV (the rwkv6 train hillclimb) must match the
    sequential recurrence, including strong decays where the exp clip binds
    and a nonzero initial state."""
    from repro.models.rwkv import wkv_chunked, _wkv_with_state
    rng = np.random.default_rng(31)
    BH, T, K = 3, 256, 32
    r = rand(rng, (BH, T, K)) * 0.5
    k = rand(rng, (BH, T, K)) * 0.5
    v = rand(rng, (BH, T, K)) * 0.5
    # decays incl. extreme channels (w ~ e^-8 per step)
    logw = -np.exp(rng.normal(size=(BH, T, K)) * 1.5)
    w = jnp.asarray(np.exp(logw).astype(np.float32))
    u = rand(rng, (BH, K)) * 0.5
    S0 = rand(rng, (BH, K, K)) * 0.3
    out_c, S_c = wkv_chunked(r, k, v, w, u, S0, chunk=64)
    out_s, S_s = _wkv_with_state(r.astype(jnp.float32),
                                 k.astype(jnp.float32),
                                 v.astype(jnp.float32),
                                 w.astype(jnp.float32), u,
                                 S0.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_s),
                               rtol=2e-3, atol=2e-3)


def test_wkv_chunked_gradable():
    from repro.models.rwkv import wkv_chunked
    rng = np.random.default_rng(32)
    BH, T, K = 2, 128, 16
    args = [rand(rng, (BH, T, K)) * 0.5 for _ in range(3)]
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(BH, T, K)) * 0.5)
                           ).astype(np.float32))
    u = rand(rng, (BH, K))
    S0 = jnp.zeros((BH, K, K), jnp.float32)
    g = jax.grad(lambda r, k, v: wkv_chunked(r, k, v, w, u, S0)[0].sum(),
                 argnums=(0, 1, 2))(*args)
    for gi in g:
        assert bool(jnp.isfinite(gi).all())
