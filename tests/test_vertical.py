"""Column solver tests: matrix-free r/w solvers vs dense D_vu/D_vd assembly,
block-Thomas vs dense solve, mass blocks consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import geometry, mesh2d, vertical

NL = 5


@pytest.fixture(scope="module")
def geom():
    m = mesh2d.rect_mesh(4, 3, 1.0, 1.0, jitter=0.2, seed=5)
    return geometry.geom2d_from_mesh(m, dtype=jnp.float64)


def mh_dense(geom):
    """(nt, 3, 3) P1 mass matrices."""
    A = np.asarray(geom.area)
    base = np.array([[2.0, 1, 1], [1, 2, 1], [1, 1, 2]]) / 12.0
    return A[:, None, None] * base


def dvu_dense(geom, nl):
    """Paper §2.3 D_vu (pressure gradient, top-down), (nt, 6nl, 6nl).

    Rows (l,t): M r_b^{l-1} - (M/2)(r_t^l + r_b^l)   [l=0: BC term to RHS]
    Rows (l,b): (M/2)(r_t^l - r_b^l)
    """
    Mh = mh_dense(geom)
    nt = Mh.shape[0]
    n = 6 * nl
    A = np.zeros((nt, n, n))
    for l in range(nl):
        t = slice(6 * l, 6 * l + 3)
        b = slice(6 * l + 3, 6 * l + 6)
        A[:, t, t] += -0.5 * Mh
        A[:, t, b] += -0.5 * Mh
        A[:, b, t] += 0.5 * Mh
        A[:, b, b] += -0.5 * Mh
        if l > 0:
            bp = slice(6 * (l - 1) + 3, 6 * (l - 1) + 6)
            A[:, t, bp] += Mh
    return A


def dvd_dense(geom, nl):
    """Paper §2.3 D_vd (vertical velocity, bottom-up).

    Rows (l,t): (M/2)(w_t^l - w_b^l)
    Rows (l,b): (M/2)(w_t^l + w_b^l) - M w_t^{l+1}  [l=nl-1: BC to RHS]
    """
    Mh = mh_dense(geom)
    nt = Mh.shape[0]
    n = 6 * nl
    A = np.zeros((nt, n, n))
    for l in range(nl):
        t = slice(6 * l, 6 * l + 3)
        b = slice(6 * l + 3, 6 * l + 6)
        A[:, t, t] += 0.5 * Mh
        A[:, t, b] += -0.5 * Mh
        A[:, b, t] += 0.5 * Mh
        A[:, b, b] += 0.5 * Mh
        if l < nl - 1:
            tn = slice(6 * (l + 1), 6 * (l + 1) + 3)
            A[:, b, tn] += -Mh
    return A


def test_solve_r_vs_dense(geom):
    nt = geom.nt
    rng = np.random.default_rng(0)
    F = jnp.asarray(rng.normal(size=(NL, 6, nt)))
    r_surf = jnp.asarray(rng.normal(size=(3, nt)))
    r = vertical.solve_r(geom, F, r_surf)
    # dense: rows (0,t) RHS must subtract the surface term  M r_s
    Mh = mh_dense(geom)
    Fd = np.moveaxis(np.asarray(F).reshape(NL * 6, nt), -1, 0).copy()
    Fd[:, 0:3] -= np.einsum("tij,tj->ti", Mh, np.asarray(r_surf).T)
    A = dvu_dense(geom, NL)
    x = np.linalg.solve(A, Fd[..., None])[..., 0]
    np.testing.assert_allclose(
        np.asarray(r).reshape(NL * 6, nt).T, x, rtol=1e-9, atol=1e-10)


def test_solve_w_vs_dense(geom):
    nt = geom.nt
    rng = np.random.default_rng(1)
    F = jnp.asarray(rng.normal(size=(NL, 6, nt)))
    w_floor = jnp.asarray(rng.normal(size=(3, nt)))
    w = vertical.solve_w(geom, F, w_floor)
    Mh = mh_dense(geom)
    Fd = np.moveaxis(np.asarray(F).reshape(NL * 6, nt), -1, 0).copy()
    # rows (nl-1, b): RHS gets + M w_floor
    Fd[:, 6 * (NL - 1) + 3:6 * NL] += np.einsum(
        "tij,tj->ti", Mh, np.asarray(w_floor).T)
    A = dvd_dense(geom, NL)
    x = np.linalg.solve(A, Fd[..., None])[..., 0]
    np.testing.assert_allclose(
        np.asarray(w).reshape(NL * 6, nt).T, x, rtol=1e-9, atol=1e-10)


def test_solve_r_vector_components(geom):
    """r solver must broadcast over leading component axes."""
    nt = geom.nt
    rng = np.random.default_rng(2)
    F = jnp.asarray(rng.normal(size=(2, NL, 6, nt)))
    rs = jnp.asarray(rng.normal(size=(2, 3, nt)))
    r = vertical.solve_r(geom, F, rs)
    r0 = vertical.solve_r(geom, F[0], rs[0])
    np.testing.assert_allclose(np.asarray(r[0]), np.asarray(r0), rtol=1e-12)


@pytest.fixture(scope="module")
def random_blocks(geom):
    """A well-conditioned random block-tridiagonal operator."""
    rng = np.random.default_rng(3)
    nt = geom.nt
    mk = lambda: jnp.asarray(0.1 * rng.normal(size=(NL, 6, 6, nt)))
    lo = mk().at[0].set(0.0)
    up = mk().at[-1].set(0.0)
    dg = mk() + 2.0 * jnp.eye(6)[None, :, :, None]
    return vertical.Blocks(lo=lo, dg=dg, up=up)


def test_block_thomas_vs_dense(geom, random_blocks):
    nt = geom.nt
    rng = np.random.default_rng(4)
    rhs = jnp.asarray(rng.normal(size=(2, NL, 6, nt)))
    x = vertical.block_thomas_solve(random_blocks, rhs)
    A = np.asarray(vertical.blocks_dense(random_blocks))
    bd = np.moveaxis(np.asarray(rhs).reshape(2, NL * 6, nt), -1, 0)  # (nt,2,6nl)
    xd = np.linalg.solve(A[:, None], bd[..., None])[..., 0]          # (nt,2,6nl)
    np.testing.assert_allclose(
        np.moveaxis(np.asarray(x).reshape(2, NL * 6, nt), -1, 0), xd,
        rtol=1e-8, atol=1e-9)


def test_blocks_matvec_vs_dense(geom, random_blocks):
    nt = geom.nt
    rng = np.random.default_rng(5)
    u = jnp.asarray(rng.normal(size=(NL, 6, nt)))
    y = vertical.blocks_matvec(random_blocks, u)
    A = np.asarray(vertical.blocks_dense(random_blocks))
    yd = np.einsum("tij,tj->ti", A, np.asarray(u).reshape(NL * 6, nt).T)
    np.testing.assert_allclose(np.asarray(y).reshape(NL * 6, nt).T, yd,
                               rtol=1e-10, atol=1e-12)


def test_mass_blocks_and_solve(geom):
    nt = geom.nt
    rng = np.random.default_rng(6)
    jz = jnp.asarray(1.0 + 0.3 * rng.random(size=(3, nt)))
    u = jnp.asarray(rng.normal(size=(NL, 6, nt)))
    mb = vertical.mass_blocks(geom, jz, NL)
    mu1 = jnp.einsum("lijt,ljt->lit", mb, u)
    mu2 = vertical.mass_apply3d(geom, jz, u)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu2),
                               rtol=1e-10, atol=1e-12)
    back = vertical.mass_solve3d(geom, jz, mu2)
    np.testing.assert_allclose(np.asarray(back), np.asarray(u),
                               rtol=1e-8, atol=1e-10)


def test_mass_total_volume(geom):
    """sum over all DOFs of M@1 = total volume = sum(2*A*jz_mean*nl)."""
    nt = geom.nt
    jz = jnp.full((3, nt), 0.5)
    one = jnp.ones((NL, 6, nt))
    tot = float(vertical.mass_apply3d(geom, jz, one).sum())
    # each prism: volume = integral Jh*Jz over parent = 2A*jz ; nl layers
    expect = float((2 * geom.area * 0.5).sum()) * NL
    np.testing.assert_allclose(tot, expect, rtol=1e-10)


def test_assembled_operator_conservation(geom):
    """The advective part of the vertical operator must telescope: summing
    F_3D^v(u) over all vertical DOFs of a column leaves only surface/floor
    fluxes (which vanish when wface=0 there) — discrete conservation."""
    nt = geom.nt
    rng = np.random.default_rng(7)
    jz = jnp.asarray(0.4 + 0.2 * rng.random(size=(3, nt)))
    H = jz * 2 * NL
    wrel = jnp.asarray(rng.normal(size=(NL, 6, nt)))
    wface = jnp.asarray(rng.normal(size=(NL + 1, 3, nt)))
    wface = wface.at[0].set(0.0).at[NL].set(0.0)
    kappa = jnp.zeros((NL, 6, nt))  # pure advection
    blocks = vertical.assemble_vertical_operator(
        geom, NL, jz, wrel, wface, kappa, H)
    u = jnp.ones((NL, 6, nt))  # constant field
    y = vertical.blocks_matvec(blocks, u)
    # For u=const the face fluxes telescope; the volume term integrates
    # d(phi)/dz of a constant... sum over vertical DOFs must be 0
    tot = y[:, 0:3, :].sum(axis=0) + y[:, 3:6, :].sum(axis=0)
    np.testing.assert_allclose(np.asarray(tot), 0.0, atol=1e-10)


def test_viscous_operator_symmetric_negative(geom):
    """Pure vertical viscosity (no advection): the operator restricted to a
    column must be dissipative: u^T A u <= 0 for the viscous part."""
    nt = geom.nt
    rng = np.random.default_rng(8)
    jz = jnp.asarray(0.4 + 0.2 * rng.random(size=(3, nt)))
    H = jz * 2 * NL
    wrel = jnp.zeros((NL, 6, nt))
    wface = jnp.zeros((NL + 1, 3, nt))
    kappa = jnp.asarray(0.01 + 0.005 * rng.random(size=(NL, 6, nt)))
    blocks = vertical.assemble_vertical_operator(
        geom, NL, jz, wrel, wface, kappa, H)
    u = jnp.asarray(rng.normal(size=(NL, 6, nt)))
    y = vertical.blocks_matvec(blocks, u)
    energy = float((u * y).sum())
    assert energy < 0.0


def test_implicit_solve_system(geom):
    """(M - dt A) u1 = M u0: u1 must satisfy the system (round-trip)."""
    nt = geom.nt
    rng = np.random.default_rng(9)
    jz = jnp.asarray(0.4 + 0.2 * rng.random(size=(3, nt)))
    H = jz * 2 * NL
    wrel = jnp.asarray(0.1 * rng.normal(size=(NL, 6, nt)))
    wface = 0.1 * jnp.asarray(rng.normal(size=(NL + 1, 3, nt)))
    wface = wface.at[0].set(0.0).at[NL].set(0.0)
    kappa = jnp.asarray(0.01 * (1 + rng.random(size=(NL, 6, nt))))
    A = vertical.assemble_vertical_operator(geom, NL, jz, wrel, wface, kappa, H)
    M = vertical.mass_blocks(geom, jz, NL)
    dt = 0.5
    sys = vertical.Blocks(lo=-dt * A.lo, dg=M - dt * A.dg, up=-dt * A.up)
    u0 = jnp.asarray(rng.normal(size=(2, NL, 6, nt)))
    rhs = jnp.stack([vertical.mass_apply3d(geom, jz, u0[i]) for i in range(2)])
    u1 = vertical.block_thomas_solve(sys, rhs)
    resid = jnp.stack([vertical.blocks_matvec(sys, u1[i]) for i in range(2)]) - rhs
    np.testing.assert_allclose(np.asarray(resid), 0.0, atol=1e-9)
