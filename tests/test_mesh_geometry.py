"""Mesh, geometry and layout unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import geometry, layout, mesh2d


@pytest.fixture(scope="module")
def mesh():
    return mesh2d.rect_mesh(8, 6, 2.0, 1.5, jitter=0.2, seed=1)


@pytest.fixture(scope="module")
def geom(mesh):
    return geometry.geom2d_from_mesh(mesh)


def test_mesh_valid(mesh):
    mesh.validate()
    assert mesh.nt == 2 * 8 * 6


def test_total_area(mesh):
    assert np.isclose(mesh.areas().sum(), 2.0 * 1.5, rtol=1e-12)


def test_hilbert_locality():
    """Hilbert reordering must reduce the fraction of neighbour accesses that
    cross a 128-wide cell boundary (the paper's cache-locality argument)."""
    m = mesh2d.rect_mesh(64, 64, 1.0, 1.0, jitter=0.0, hilbert=False)
    mh = m.hilbert_reorder()
    def block_cross_fraction(mm, block=128):
        idx = np.arange(mm.nt)[:, None]
        cross = (mm.neigh_tri // block) != (idx // block)
        return cross[mm.edge_type == mesh2d.INTERIOR].mean()
    assert block_cross_fraction(mh) < 0.6 * block_cross_fraction(m)


def test_normals_outward(mesh, geom):
    # edge midpoint + eps*normal must leave the triangle (cross-check via
    # centroid: normal points away from centroid)
    c = mesh.centroids()  # (nt,2)
    px = np.asarray(geom.node_x).T  # (nt,3)
    py = np.asarray(geom.node_y).T
    for e in range(3):
        a, b = mesh2d.EDGE_NODES[e]
        mx = 0.5 * (px[:, a] + px[:, b])
        my = 0.5 * (py[:, a] + py[:, b])
        dot = (np.asarray(geom.edge_nx)[e] * (mx - c[:, 0])
               + np.asarray(geom.edge_ny)[e] * (my - c[:, 1]))
        assert (dot > 0).all()


def test_gradient_exact_linear(geom):
    """grad of f = 2x - 3y must be (2, -3) everywhere."""
    f = 2.0 * geom.node_x - 3.0 * geom.node_y
    g = geometry.grad2d(geom, f)
    np.testing.assert_allclose(np.asarray(g[0]), 2.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g[1]), -3.0, rtol=1e-5)


def test_mass_matrix_roundtrip(geom):
    f = jnp.sin(geom.node_x) + geom.node_y
    np.testing.assert_allclose(
        np.asarray(geometry.minv_apply(geom, geometry.mass_apply(geom, f))),
        np.asarray(f), rtol=1e-5, atol=1e-6)


def test_mass_integral(geom):
    """sum over nodes of M @ 1 = total area."""
    one = jnp.ones_like(geom.node_x)
    total = geometry.mass_apply(geom, one).sum()
    assert np.isclose(float(total), float(geom.area.sum()), rtol=1e-6)


def test_divergence_theorem(geom):
    """<grad phi . F> - <<phi n.F>> = -<phi div F> ; for constant F and the
    sum over all test functions of one element: boundary integral equals
    volume gradient term (discrete Gauss identity on each triangle)."""
    Fx, Fy = 1.3, -0.7
    # sum_i <dphi_i . F> = 0 since sum of basis = 1 (constant)
    s = (geom.dphi[:, 0] * Fx + geom.dphi[:, 1] * Fy).sum(axis=0)
    np.testing.assert_allclose(np.asarray(s), 0.0, atol=1e-4)
    # per-triangle: sum_e l_e n_e = 0
    zx = (geom.edge_len * geom.edge_nx).sum(axis=0)
    zy = (geom.edge_len * geom.edge_ny).sum(axis=0)
    np.testing.assert_allclose(np.asarray(zx), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(zy), 0.0, atol=1e-4)


def test_edge_ext_matches_int_for_continuous(geom):
    """For a globally continuous field (function of x,y), ext values at edge
    quadrature points equal int values on interior edges."""
    f = 1.0 + 0.5 * geom.node_x - 0.25 * geom.node_y
    fi = geometry.edge_interp(f)
    fe = geometry.edge_interp_ext(geom, f)
    mask = np.asarray(geom.interior)[:, None, :]
    np.testing.assert_allclose(np.asarray((fi - fe) * mask), 0.0, atol=1e-5)


def test_edge_scatter_constant(geom):
    """∫_edge phi_i 1 over all edges of a triangle = perimeter-weighted masses:
    row sum per node = sum of half-lengths of adjacent edges."""
    g = jnp.ones((3, 2, geom.nt))
    out = np.asarray(geometry.edge_scatter(geom, g))
    el = np.asarray(geom.edge_len)
    for node in range(3):
        adj = [e for e in range(3) if node in (mesh2d.EDGE_NODES[e][0],
                                               mesh2d.EDGE_NODES[e][1])]
        expect = sum(0.5 * el[e] for e in adj)
        np.testing.assert_allclose(out[node], expect, rtol=1e-5)


@settings(deadline=None, max_examples=20)
@given(nl=st.integers(1, 5), nn=st.sampled_from([3, 6]),
       nt=st.integers(1, 300))
def test_layout_roundtrip(nl, nn, nt):
    x = jnp.arange(nl * nn * nt, dtype=jnp.float32).reshape(nl, nn, nt)
    c = layout.soa_to_cell(x)
    assert c.shape[-1] == layout.CELL
    back = layout.cell_to_soa(c, nl, nn, nt)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_cell_row_order():
    """Row ordering must be layer-major then node (paper Fig. 5)."""
    nl, nn, nt = 2, 6, 128
    x = jnp.zeros((nl, nn, nt)).at[1, 4, :].set(7.0)
    c = layout.soa_to_cell(x)
    row = 1 * nn + 4
    assert float(c[0, row, 0]) == 7.0
    assert float(jnp.abs(c).sum()) == 7.0 * 128
