"""3D split-IMEX stepper integration/property tests.

These validate the *discrete consistency machinery* that the paper's scheme
is built on (SI §S2-S3):
  * tracer constancy: T == const stays exactly constant under active flow on
    a moving sigma mesh (exercises qbar/Qbar consistency, the w-tilde solve,
    the GCL and the mass matrices together),
  * global tracer conservation in a closed basin,
  * 3D lake-at-rest (well-balancedness incl. the internal pressure gradient),
  * surface flux residual ~ 0 (w-tilde at the surface matches the mesh
    velocity when the 2D/3D budgets are consistent),
  * baroclinic adjustment: qualitative response to a density front.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import dg2d, dg3d, eos, geometry, mesh2d, stepper, turbulence, vertical
from repro.core.extrusion import VGrid, layer_geometry, mesh_velocity, vsum_dofs

F64 = jnp.float64


def build(nx=6, ny=5, lx=2000.0, ly=1500.0, depth=20.0, nl=4, channel=False,
          shelf=False):
    if channel:
        m = mesh2d.channel_mesh(nx, ny, lx, ly, jitter=0.15, seed=3)
    else:
        m = mesh2d.rect_mesh(nx, ny, lx, ly, jitter=0.2, seed=3)
    geom = geometry.geom2d_from_mesh(m, dtype=F64)
    if shelf:
        bf = mesh2d.shelf_bathymetry(0.4 * depth, depth, lx)
        b = jnp.stack([jnp.asarray(bf(np.stack(
            [np.asarray(geom.node_x[i]), np.asarray(geom.node_y[i])], 1)))
            for i in range(3)]).astype(F64)
    else:
        b = jnp.full((3, m.nt), depth, F64)
    vg = VGrid(b=b, nl=nl)
    return m, geom, vg


def state_with(geom, vg, eta=None, T0=10.0, S0=35.0):
    st = stepper.init_state(geom, vg, T0=T0, S0=S0, dtype=F64)
    if eta is not None:
        st = stepper.OceanState(
            ext=dg2d.State2D(eta.astype(F64), st.ext.qx, st.ext.qy),
            ux=st.ux, uy=st.uy, T=st.T, S=st.S, turb_k=st.turb_k,
            turb_eps=st.turb_eps, nu_t=st.nu_t, kappa_t=st.kappa_t,
            time=st.time)
    return st


def total_tracer(geom, vg, st, cfg):
    vge = layer_geometry(vg, st.ext.eta, cfg.h_min)
    return float(vertical.mass_apply3d(geom, vge.jz, st.T).sum())


def test_tracer_constancy_exact():
    """THE consistency test: constant T must remain constant to machine
    precision while gravity waves slosh the free surface (moving mesh,
    active transport, implicit + explicit stages)."""
    m, geom, vg = build(nl=4)
    cfg = stepper.OceanConfig(nl=4, dt=20.0, m_2d=8, exact_consistency=True,
                              use_gls=True, eos_kind="linear")
    eta0 = 0.05 * jnp.cos(jnp.pi * geom.node_x / 2000.0)
    st = state_with(geom, vg, eta=eta0)
    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
    for _ in range(5):
        st = step(st)
    err = float(jnp.abs(st.T - 10.0).max())
    errs = float(jnp.abs(st.S - 35.0).max())
    assert err < 1e-10, err
    assert errs < 1e-10, errs
    # flow must actually be active for this to be meaningful
    assert float(jnp.abs(st.ux).max()) > 1e-6


def test_constancy_holds_for_both_flux_forms():
    """A structural property of the scheme (found while validating): because
    the w-tilde solve uses the *same* lateral flux as the tracer advection,
    constancy holds to machine precision for BOTH the paper's literal flux
    and the exact-consistency refinement.  (The refinement's benefit is the
    surface flux residual — see test_surface_residual_comparison.)"""
    m, geom, vg = build(nl=4)
    eta0 = 0.05 * jnp.cos(jnp.pi * geom.node_x / 2000.0)
    for exact in (True, False):
        cfg = stepper.OceanConfig(nl=4, dt=20.0, m_2d=8,
                                  exact_consistency=exact, use_gls=False,
                                  eos_kind="linear")
        st = state_with(geom, vg, eta=eta0)
        step = jax.jit(lambda s, c=cfg: stepper.step(geom, vg, c, s))
        for _ in range(5):
            st = step(st)
        assert float(jnp.abs(st.T - 10.0).max()) < 1e-10, exact


def test_surface_residual_comparison():
    """The exact-consistency flux (stage-weighted Fbar_edge) must drive the
    surface residual w~(surface) - w_m orders of magnitude below the paper's
    literal flux form (which leaves the time-mean-vs-endpoint LF mismatch)."""
    m, geom, vg = build(nl=4)
    eta0 = 0.05 * jnp.cos(jnp.pi * geom.node_x / 2000.0)
    resid = {}
    for exact in (True, False):
        cfg = stepper.OceanConfig(nl=4, dt=20.0, m_2d=8,
                                  exact_consistency=exact, use_gls=False,
                                  eos_kind="linear")
        st = state_with(geom, vg, eta=eta0)
        turb0 = turbulence.TurbState(st.turb_k, st.turb_eps, st.nu_t,
                                     st.kappa_t)
        out = stepper.stage(geom, vg, cfg, st, st.ux, st.uy, st.T, st.S,
                            st.ext.eta, turb0, cfg.dt / 2, 4, True,
                            stepper.Forcing3D())
        wm = mesh_velocity(vg, st.ext.eta, out.ext.eta, cfg.dt / 2)
        resid[exact] = float(jnp.abs(out.w_tilde[0, 0:3, :] - wm[0]).max())
    assert resid[True] < 1e-6 * resid[False], resid


def test_tracer_conservation_closed():
    """Total tracer content in a closed basin is exactly conserved."""
    m, geom, vg = build(nl=4, shelf=True)
    cfg = stepper.OceanConfig(nl=4, dt=20.0, m_2d=8, use_gls=True,
                              eos_kind="linear")
    eta0 = 0.05 * jnp.cos(jnp.pi * geom.node_x / 2000.0)
    st = state_with(geom, vg, eta=eta0)
    # non-constant tracer blob
    blob = 10.0 + 2.0 * jnp.exp(
        -((geom.node_x - 600.0) ** 2 + (geom.node_y - 700.0) ** 2) / 3e5)
    T = jnp.broadcast_to(jnp.concatenate([blob, blob])[None], st.T.shape)
    st = stepper.OceanState(ext=st.ext, ux=st.ux, uy=st.uy, T=T, S=st.S,
                            turb_k=st.turb_k, turb_eps=st.turb_eps,
                            nu_t=st.nu_t, kappa_t=st.kappa_t, time=st.time)
    tot0 = total_tracer(geom, vg, st, cfg)
    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
    for _ in range(5):
        st = step(st)
    tot1 = total_tracer(geom, vg, st, cfg)
    assert abs(tot1 - tot0) < 1e-9 * abs(tot0), (tot0, tot1)
    # blob must have moved/diffused at least a little (flow active)
    assert float(jnp.abs(st.T - T).max()) > 1e-8


def test_lake_at_rest_3d():
    """eta=0, u=0, uniform T,S over a *shelf* bathymetry stays at rest
    (the internal pressure gradient r must vanish for uniform density)."""
    m, geom, vg = build(nl=4, shelf=True)
    cfg = stepper.OceanConfig(nl=4, dt=30.0, m_2d=8, use_gls=False,
                              eos_kind="linear")
    st = state_with(geom, vg)
    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
    for _ in range(3):
        st = step(st)
    assert float(jnp.abs(st.ext.eta).max()) < 1e-10
    assert float(jnp.abs(st.ux).max()) < 1e-10
    assert float(jnp.abs(st.uy).max()) < 1e-10


def test_pressure_gradient_uniform_density():
    """r must be exactly 0 for uniform rho' regardless of eta shape."""
    m, geom, vg = build(nl=5)
    eta = 0.2 * jnp.sin(geom.node_x / 300.0) * jnp.cos(geom.node_y / 250.0)
    vge = layer_geometry(vg, eta)
    rho = jnp.full((5, 6, m.nt), 0.0, F64)  # rho' = 0
    F, r_s = dg3d.pressure_gradient_rhs(geom, vg, vge, rho)
    r = vertical.solve_r(geom, F, r_s)
    np.testing.assert_allclose(np.asarray(r), 0.0, atol=1e-12)


def test_pressure_gradient_linear_stratification():
    """For rho' = rho'(z) only (flat layers: eta=0, flat bottom), the
    horizontal pressure gradient r must vanish."""
    m, geom, vg = build(nl=5, depth=20.0)
    vge = layer_geometry(vg, jnp.zeros((3, m.nt), F64))
    from repro.core.extrusion import node_z
    z = node_z(vg, vge)
    rho = -0.01 * z  # denser with depth
    F, r_s = dg3d.pressure_gradient_rhs(geom, vg, vge, rho)
    r = vertical.solve_r(geom, F, r_s)
    np.testing.assert_allclose(np.asarray(r), 0.0, atol=1e-10)


def test_surface_flux_residual():
    """Under exact consistency the solved w-tilde at the free surface must
    equal the mesh velocity there (zero advective flux through the surface)."""
    m, geom, vg = build(nl=4)
    cfg = stepper.OceanConfig(nl=4, dt=20.0, m_2d=8, use_gls=False,
                              eos_kind="linear")
    eta0 = 0.05 * jnp.cos(jnp.pi * geom.node_x / 2000.0)
    st = state_with(geom, vg, eta=eta0)
    turb0 = turbulence.TurbState(st.turb_k, st.turb_eps, st.nu_t, st.kappa_t)
    out = stepper.stage(geom, vg, cfg, st, st.ux, st.uy, st.T, st.S,
                        st.ext.eta, turb0, cfg.dt / 2, 4, True,
                        stepper.Forcing3D())
    wm = mesh_velocity(vg, st.ext.eta, out.ext.eta, cfg.dt / 2)
    resid = out.w_tilde[0, 0:3, :] - wm[0]
    scale = float(jnp.abs(wm[0]).max()) + 1e-30
    assert float(jnp.abs(resid).max()) < 1e-9 * max(scale, 1e-6), (
        float(jnp.abs(resid).max()), scale)


def test_baroclinic_adjustment():
    """Warm (light) water on the left, cold on the right, closed basin:
    the front must slump — surface flow toward the dense side, bottom flow
    toward the light side (opposite signs), and KE must grow from zero."""
    m, geom, vg = build(nx=10, ny=4, lx=4000.0, ly=1000.0, depth=20.0, nl=6)
    cfg = stepper.OceanConfig(nl=6, dt=30.0, m_2d=10, use_gls=True,
                              eos_kind="linear")
    st = state_with(geom, vg)
    # T: 14 C on the left half, 6 C on the right (rho' = -alpha (T - T0))
    Tfield = 10.0 + 4.0 * jnp.tanh((2000.0 - geom.node_x) / 400.0)
    T = jnp.broadcast_to(jnp.concatenate([Tfield, Tfield])[None],
                         st.T.shape).astype(F64)
    st = stepper.OceanState(ext=st.ext, ux=st.ux, uy=st.uy, T=T, S=st.S,
                            turb_k=st.turb_k, turb_eps=st.turb_eps,
                            nu_t=st.nu_t, kappa_t=st.kappa_t, time=st.time)
    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
    for _ in range(10):
        st = step(st)
    # surface vs bottom x-velocity, basin-averaged
    us = float(st.ux[0, 0:3, :].mean())
    ub = float(st.ux[-1, 3:6, :].mean())
    assert np.isfinite(us) and np.isfinite(ub)
    # warm/light water spreads over the top toward +x; return flow at depth
    assert us > 0.0, (us, ub)
    assert ub < 0.0, (us, ub)
    assert us > 1e-5


def test_tidal_channel_3d_smoke():
    """Open-boundary tidal forcing in a 3D channel: stable, finite, and the
    tracer stays within bounds with constant open-boundary values."""
    m, geom, vg = build(nx=8, ny=3, lx=4000.0, ly=900.0, depth=10.0, nl=4,
                        channel=True)
    cfg = stepper.OceanConfig(nl=4, dt=20.0, m_2d=10, use_gls=True,
                              eos_kind="linear")
    st = state_with(geom, vg)
    eta_bc = 0.1 * jnp.exp(-geom.node_x / 800.0)
    T_open = jnp.full_like(st.T, 10.0)
    forcing = stepper.Forcing3D(
        forcing2d=dg2d.Forcing2D(eta_open=eta_bc),
        T_open=T_open, S_open=jnp.full_like(st.S, 35.0))
    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s, forcing))
    for _ in range(10):
        st = step(st)
    assert bool(jnp.isfinite(st.ux).all())
    assert float(jnp.abs(st.ux).max()) > 1e-6   # tide drives flow
    assert float(jnp.abs(st.T - 10.0).max()) < 1e-8  # constancy incl. open BC
