"""Fused horizontal-RHS pipeline tests (ISSUE 4).

Covers:
  * f64 step-equivalence of the fused pipeline (EdgeCache / TransportCache /
    FieldStates + batched momentum/tracer RHS) vs the per-call ref path on a
    channel mesh with interior, WALL and OPEN edges,
  * the Pallas lateral-flux kernel vs its jnp oracle (ragged column counts)
    and vs the qp-level lat_scatter construction,
  * tracer constancy under exact_consistency=True through the fused +
    kernel path,
  * the STRUCTURAL one-per-stage interpolation reuse: exterior edge gathers
    of jz / transport happen exactly once per stage (call-count assert),
  * edge_scatter's unrolled scatter-tensor form vs the seed .at[].add loop.
"""
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import dg2d, dg3d, geometry, horizontal, mesh2d, stepper
from repro.core import turbulence
from repro.core.extrusion import VGrid, layer_geometry
from repro.kernels import horizontal_flux, ops
from repro.kernels import ref as kref

F64 = jnp.float64


def build_channel(nl=4, nx=8, ny=3, depth=10.0):
    m = mesh2d.channel_mesh(nx, ny, 4000.0, 900.0, jitter=0.15, seed=3)
    geom = geometry.geom2d_from_mesh(m, dtype=F64)
    b = jnp.full((3, m.nt), depth, F64)
    return m, geom, VGrid(b=b, nl=nl)


def tidal_setup(nl=4):
    m, geom, vg = build_channel(nl=nl)
    # the equivalence mesh must exercise every BC branch
    et = np.asarray(m.edge_type)
    assert (et == mesh2d.INTERIOR).any()
    assert (et == mesh2d.WALL).any()
    assert (et == mesh2d.OPEN).any()
    st = stepper.init_state(geom, vg, dtype=F64)
    eta0 = 0.05 * jnp.cos(jnp.pi * geom.node_x / 4000.0)
    st = dataclasses.replace(st, ext=dg2d.State2D(eta0, st.ext.qx, st.ext.qy))
    forc = stepper.Forcing3D(
        forcing2d=dg2d.Forcing2D(eta_open=0.1 * jnp.exp(-geom.node_x / 800.0)),
        T_open=jnp.full_like(st.T, 10.0), S_open=jnp.full_like(st.S, 35.0))
    return geom, vg, st, forc


def _steps(geom, vg, cfg, st, forc, n=3):
    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s, forc))
    for _ in range(n):
        st = step(st)
    return st


# ---------------------------------------------------------------------------
# step-level equivalence
# ---------------------------------------------------------------------------
def test_step_equivalence_fused_vs_ref():
    """Fused pipeline must reproduce the per-call ref path to f64 roundoff
    over full steps (interior + WALL + OPEN edges, tidal forcing)."""
    geom, vg, st, forc = tidal_setup()
    cfg_ref = stepper.OceanConfig(nl=4, dt=20.0, m_2d=4, use_gls=True,
                                  backend="ref", fused_horizontal=False)
    cfg_fus = dataclasses.replace(cfg_ref, fused_horizontal=True)
    a = _steps(geom, vg, cfg_ref, st, forc)
    b = _steps(geom, vg, cfg_fus, st, forc)
    for name in ("ux", "uy", "T", "S"):
        xa, xb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        scale = max(np.abs(xa).max(), 1.0)
        assert np.abs(xa - xb).max() < 1e-12 * scale, (
            name, np.abs(xa - xb).max())
    np.testing.assert_allclose(np.asarray(a.ext.eta), np.asarray(b.ext.eta),
                               rtol=0, atol=1e-12)
    assert np.abs(np.asarray(a.ux)).max() > 1e-6   # flow is active


def test_step_equivalence_kernel_backend():
    """The Pallas lateral-flux kernel path (interpret mode on CPU) must
    match the fused ref path to f64 roundoff."""
    geom, vg, st, forc = tidal_setup()
    cfg_ref = stepper.OceanConfig(nl=4, dt=20.0, m_2d=4, use_gls=True,
                                  backend="ref")
    cfg_pal = dataclasses.replace(cfg_ref, backend="pallas_interpret")
    a = _steps(geom, vg, cfg_ref, st, forc)
    b = _steps(geom, vg, cfg_pal, st, forc)
    for name in ("ux", "uy", "T", "S"):
        xa, xb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        scale = max(np.abs(xa).max(), 1.0)
        assert np.abs(xa - xb).max() < 1e-11 * scale, (
            name, np.abs(xa - xb).max())


def test_tracer_constancy_fused_exact():
    """Regression: the fused pipeline + kernel backend must preserve the
    machine-precision tracer constancy of the exact-consistency scheme."""
    geom, vg, st, forc = tidal_setup()
    cfg = stepper.OceanConfig(nl=4, dt=20.0, m_2d=4, use_gls=True,
                              exact_consistency=True,
                              backend="pallas_interpret")
    out = _steps(geom, vg, cfg, st, forc, n=5)
    assert float(jnp.abs(out.T - 10.0).max()) < 1e-10
    assert float(jnp.abs(out.S - 35.0).max()) < 1e-10
    assert float(jnp.abs(out.ux).max()) > 1e-6


# ---------------------------------------------------------------------------
# kernel vs oracle vs qp-level construction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("C", [1, 60, 129])
def test_lateral_flux_kernel_vs_oracle_ragged(C):
    rng = np.random.default_rng(C)
    nl = 3
    f = jnp.asarray(rng.normal(size=(nl * 6, C)))
    fext = jnp.asarray(rng.normal(size=(nl * 12, C)))
    speed = jnp.asarray(rng.normal(size=(nl * 12, C)))
    wq = jnp.asarray(np.abs(rng.normal(size=(6, C))) + 0.1)
    out = horizontal_flux.lateral_flux_cell(f, fext, speed, wq,
                                            interpret=True)
    exp = kref.lateral_flux_cell(f, fext, speed, wq)
    assert out.shape == (nl * 6, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-12, atol=1e-12)


def test_lateral_flux_term_matches_qp_scatter():
    """The SoA dispatch wrapper (oracle AND kernel) must equal the qp-level
    construction lat_scatter(where(speed>0, fi, fe) * speed)."""
    m, geom, vg = build_channel(nl=3)
    nl, nt = 3, geom.nt
    rng = np.random.default_rng(7)
    f = jnp.asarray(rng.normal(size=(2, nl, 6, nt)))
    speed = jnp.asarray(rng.normal(size=(nl, 2, 3, 2, nt)))
    flux = dg3d.LateralFlux(speed=speed,
                            upwind=(speed > 0).astype(speed.dtype))
    fx = dg3d.edge_ext_nodal6(geom, f)
    fi = dg3d.lat_interp(f)
    fe = dg3d.lat_ext_from_nodal(fx)
    exp = dg3d.lat_scatter(geom, jnp.where(flux.upwind > 0.5, fi, fe)
                           * speed[None])
    for backend in ("ref", "pallas_interpret"):
        out = ops.lateral_flux_term(geom, f, fx, speed, backend=backend)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-12, atol=1e-12, err_msg=backend)


def test_field_states_nodal_matches_qp():
    """Nodal-gather exterior states (with wall reflection + open blend)
    must match the seed qp-level construction."""
    m, geom, vg = build_channel(nl=3)
    nl, nt = 3, geom.nt
    rng = np.random.default_rng(9)
    f = jnp.asarray(rng.normal(size=(2, nl, 6, nt)))
    opens = jnp.asarray(rng.normal(size=(2, nl, 6, nt)))
    for kw in (dict(bc_reflect=True), dict(open_values=opens), dict()):
        a = dg3d.field_states(geom, f, nodal=True, **kw)
        b = dg3d.field_states(geom, f, nodal=False, **kw)
        np.testing.assert_allclose(np.asarray(a.fe), np.asarray(b.fe),
                                   rtol=1e-13, atol=1e-13, err_msg=str(kw))
        np.testing.assert_array_equal(np.asarray(a.fi), np.asarray(b.fi))


def test_advdiff_cached_matches_uncached():
    """horizontal_advdiff with the full cache stack == without (ref)."""
    m, geom, vg = build_channel(nl=4)
    nl, nt = 4, geom.nt
    vge = layer_geometry(vg, 0.02 * jnp.cos(geom.node_x / 500.0))
    rng = np.random.default_rng(11)
    r3 = lambda: jnp.asarray(rng.normal(size=(nl, 6, nt)))
    ux, uy = 0.1 + 0.05 * r3(), 0.05 * r3()
    u_pair = jnp.stack([ux, uy])
    q = dg3d.transport_from_velocity(vge, ux, uy)
    nu = jnp.abs(r3()) + 0.1
    eta = vge.eta
    hc = horizontal.stage_cache(geom, vge)
    tc = horizontal.transport_cache(geom, vge, vg, hc, q[0], q[1])
    fs = dg3d.field_states(geom, u_pair, bc_reflect=True)
    flux_ref = dg3d.lateral_flux_speed(geom, vge, vg, q[0], q[1], eta, vg.b)
    np.testing.assert_allclose(np.asarray(tc.flux.speed),
                               np.asarray(flux_ref.speed),
                               rtol=1e-13, atol=1e-14)
    out_ref = dg3d.horizontal_advdiff(geom, vge, nl, u_pair, q[0], q[1],
                                      flux_ref, nu, bc_reflect=True)
    out_fus = dg3d.horizontal_advdiff(geom, vge, nl, u_pair, q[0], q[1],
                                      tc.flux, nu, bc_reflect=True,
                                      cache=hc, tcache=tc, fcache=fs)
    scale = float(jnp.abs(out_ref).max())
    np.testing.assert_allclose(np.asarray(out_fus), np.asarray(out_ref),
                               rtol=0, atol=1e-12 * scale)


# ---------------------------------------------------------------------------
# structural: one-per-stage interpolation reuse (call counts)
# ---------------------------------------------------------------------------
def _count_stage_gathers(monkeypatch, cfg, geom, vg, st, forc):
    """Run one eager stage and count exterior edge gathers issued by the 3D
    horizontal pipeline (modules dg3d/horizontal; the 2D external burst is
    excluded — its gathers are unrelated to this refactor)."""
    counts = {"ext_interp": 0, "ext_nodal": 0}
    orig_ext = geometry.edge_interp_ext
    orig_nodal = dg3d.edge_ext_nodal6

    def count_ext(g, f):
        mod = sys._getframe(1).f_globals.get("__name__", "")
        if mod in ("repro.core.dg3d", "repro.core.horizontal"):
            counts["ext_interp"] += 1
        return orig_ext(g, f)

    def count_nodal(g, f):
        counts["ext_nodal"] += 1
        return orig_nodal(g, f)

    monkeypatch.setattr(geometry, "edge_interp_ext", count_ext)
    monkeypatch.setattr(dg3d, "edge_ext_nodal6", count_nodal)
    turb0 = turbulence.TurbState(st.turb_k, st.turb_eps, st.nu_t, st.kappa_t)
    stepper.stage(geom, vg, cfg, st, st.ux, st.uy, st.T, st.S, st.ext.eta,
                  turb0, cfg.dt / 2, 2, True, forc)
    return counts


def test_stage_gather_counts(monkeypatch):
    """THE structural assert of the tentpole: with the fused pipeline every
    field-independent exterior edge gather happens exactly once per stage.

    Fused budget (exact_consistency=True):
      stage_cache:        jz, Jz/H, H, eta            -> 4   (jz ONCE)
      flux speed (pred):  qx, qy                      -> 2   (per transport)
      flux speed (qbar):  qx, qy, Qbar_x, Qbar_y      -> 4
      pressure gradient:  rho                         -> 1
      diffusion:          nu_h, kappa_h               -> 2
      total edge_interp_ext                           = 13
      field neighbour gathers (edge_ext_nodal6)       = 2   (velocity+tracer)

    Seed budget: pressure 2 (rho, jz) + flux speeds 2x5 (qx, qy, Jz/H, +2)
    + advdiff 3x3 (field, jz, nu) = 21, all at qp width."""
    geom, vg, st, forc = tidal_setup()
    cfg_fus = stepper.OceanConfig(nl=4, dt=20.0, m_2d=4, use_gls=True,
                                  exact_consistency=True, backend="ref")
    c_fus = _count_stage_gathers(monkeypatch, cfg_fus, geom, vg, st, forc)
    assert c_fus == {"ext_interp": 13, "ext_nodal": 2}, c_fus

    cfg_ref = dataclasses.replace(cfg_fus, fused_horizontal=False)
    c_ref = _count_stage_gathers(monkeypatch, cfg_ref, geom, vg, st, forc)
    assert c_ref == {"ext_interp": 21, "ext_nodal": 0}, c_ref
    assert c_fus["ext_interp"] + c_fus["ext_nodal"] < c_ref["ext_interp"]


# ---------------------------------------------------------------------------
# edge_scatter regression (satellite: unrolled scatter tensor)
# ---------------------------------------------------------------------------
def test_edge_scatter_matches_seed_loop():
    m, geom, vg = build_channel()
    rng = np.random.default_rng(13)
    g = jnp.asarray(rng.normal(size=(2, 4, 3, 2, geom.nt)))
    got = geometry.edge_scatter(geom, g)
    # the seed implementation: per-edge .at[].add accumulation
    w = geom.edge_len[:, None, :] * jnp.asarray(geometry.W_GAUSS)[:, None]
    ga = (g * w * geometry._PHIA[:, None]).sum(axis=-2)
    gb = (g * w * geometry._PHIB[:, None]).sum(axis=-2)
    exp = jnp.zeros_like(ga)
    for e in range(3):
        exp = exp.at[..., geometry.EDGE_A[e], :].add(ga[..., e, :])
        exp = exp.at[..., geometry.EDGE_B[e], :].add(gb[..., e, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-13, atol=1e-13)
