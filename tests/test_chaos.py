"""Chaos-hardening tests: deterministic fault injection, verified
checkpoints, runner recovery semantics, and the graceful-degradation dt
ladder.

The heavyweight chaos *matrix* (every recoverable fault class bitwise-equal
to a fault-free run) runs here on a tiny ocean mesh and again in
``scripts/ci.sh --chaos-smoke``."""
import dataclasses
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.checkpoint.checkpoint import (CheckpointCorruption,
                                         CheckpointError, Checkpointer)
from repro.launch import sim_campaign
from repro.obs import diagnostics as obs_diag
from repro.obs import metrics
from repro.runtime import chaos
from repro.runtime.fault_tolerance import (LadderConfig, RunnerConfig,
                                           SimulationRunner, TrainRunner)

F64 = jnp.float64


def tree_equal(a, b) -> bool:
    la = [np.asarray(x) for x in jax.tree_util.tree_leaves(a)]
    lb = [np.asarray(x) for x in jax.tree_util.tree_leaves(b)]
    return len(la) == len(lb) and all(
        x.shape == y.shape and np.array_equal(x, y, equal_nan=True)
        for x, y in zip(la, lb))


def demo_tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.asarray(3), "d": (jnp.ones(4), jnp.zeros(2))}}


# ---------------------------------------------------------------------------
# Checkpointer: manifest, verification, fallback
# ---------------------------------------------------------------------------
def test_checkpoint_manifest_written(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, demo_tree(), blocking=True)
    meta = ck.manifest(3)
    assert meta["format"] == 2 and meta["step"] == 3
    assert set(meta["leaves"]) == set(meta["keys"])
    info = meta["leaves"]["a"]
    assert info["shape"] == [2, 3] and info["dtype"] == "float64"
    assert isinstance(info["crc32"], int)
    assert ck.verify(3) == []


def test_checkpoint_verify_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, demo_tree(), blocking=True)
    d = str(tmp_path / "step_000000001")

    # bit flip -> checksum mismatch
    fn = os.path.join(d, "a.npy")
    data = bytearray(open(fn, "rb").read())
    data[-1] ^= 0xFF
    open(fn, "wb").write(bytes(data))
    assert any("checksum" in p for p in ck.verify(1))

    # truncation -> unreadable
    with open(fn, "r+b") as fh:
        fh.truncate(os.path.getsize(fn) // 2)
    assert any("unreadable" in p or "checksum" in p for p in ck.verify(1))

    # missing leaf
    os.remove(fn)
    assert any("missing" in p for p in ck.verify(1))


def test_restore_falls_back_to_newest_intact(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    t1 = demo_tree()
    t2 = jax.tree_util.tree_map(lambda x: x + 1, t1)
    ck.save(1, t1, blocking=True)
    ck.save(2, t2, blocking=True)
    # corrupt the newest step
    fn = tmp_path / "step_000000002" / "a.npy"
    with open(fn, "r+b") as fh:
        fh.truncate(4)
    assert ck.intact_steps() == [1]
    out = ck.restore(demo_tree())           # auto: falls back to step 1
    assert tree_equal(out, t1)
    # explicit request for the corrupt step raises instead of substituting
    with pytest.raises(CheckpointCorruption):
        ck.restore(demo_tree(), step=2)


def test_latest_step_survives_bad_pointer(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    ck.save(1, demo_tree(), blocking=True)
    ck.save(2, demo_tree(), blocking=True)
    latest = tmp_path / "latest"
    latest.write_text("step_000000999")         # dangling
    assert ck.latest_step() == 2
    latest.write_text("step_000000001")         # stale
    assert ck.latest_step() == 2
    latest.unlink()                             # missing
    assert ck.latest_step() == 2


def test_restore_latest_skips_corrupt_and_reports_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    t1 = demo_tree()
    ck.save(4, t1, blocking=True)
    ck.save(6, jax.tree_util.tree_map(lambda x: x * 2, t1), blocking=True)
    os.remove(tmp_path / "step_000000006" / "b__c.npy")   # missing leaf
    out, step = ck.restore_latest(demo_tree())
    assert step == 4 and tree_equal(out, t1)
    # nothing on disk -> (None, None), the runner's cold-restore signal
    ck2 = Checkpointer(str(tmp_path / "empty"))
    assert ck2.restore_latest(demo_tree()) == (None, None)


# ---------------------------------------------------------------------------
# Checkpointer: async save failures must be loud (satellite 1)
# ---------------------------------------------------------------------------
def test_async_save_failure_reraised_from_wait(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))

    def boom(*a, **k):
        raise OSError("disk full (injected)")
    monkeypatch.setattr(np, "save", boom)      # temp-dir leaf write fails
    ck.save(1, demo_tree())                    # async: no error yet
    with pytest.raises(CheckpointError, match="disk full"):
        ck.wait()
    assert ck.latest_step() is None            # nothing pretends to exist


def test_async_save_failure_reraised_from_next_save(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))
    calls = {"n": 0}
    real_save = np.save

    def flaky(path, arr, *a, **k):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("quota exceeded (injected)")
        return real_save(path, arr, *a, **k)
    monkeypatch.setattr(np, "save", flaky)
    ck.save(1, demo_tree())
    with pytest.raises(CheckpointError, match="quota"):
        ck.save(2, demo_tree(), blocking=True)
    # the error is consumed: a fresh save goes through and verifies
    ck.save(3, demo_tree(), blocking=True)
    assert ck.latest_step() == 3 and ck.verify(3) == []


def test_chaos_io_error_site_in_worker(tmp_path):
    ck = Checkpointer(str(tmp_path))
    plan = chaos.FaultPlan([chaos.Fault("checkpoint.write", "io_error")])
    with chaos.active(plan):
        ck.save(1, demo_tree())
        with pytest.raises(CheckpointError, match="chaos"):
            ck.wait()
    assert plan.log[0]["kind"] == "io_error"
    ck.save(2, demo_tree(), blocking=True)     # disarmed: saves fine
    assert ck.latest_step() == 2


# ---------------------------------------------------------------------------
# chaos harness unit tests
# ---------------------------------------------------------------------------
def test_fault_validation_and_parse():
    with pytest.raises(ValueError, match="site"):
        chaos.Fault("nope", "poison_nan")
    with pytest.raises(ValueError, match="kind"):
        chaos.Fault("sim.state", "nope")
    f = chaos.parse_fault("poison_nan@sim.state:step=5,field=T,count=2")
    assert (f.site, f.kind, f.step, f.field, f.count) == \
        ("sim.state", "poison_nan", 5, "T", 2)
    f2 = chaos.parse_fault("stall@runner.step:seconds=0.01")
    assert f2.args == {"seconds": 0.01}


def test_site_is_identity_without_plan():
    x = {"a": jnp.ones(3)}
    assert chaos.site("sim.state", x, step=0) is x


def test_poison_is_deterministic_and_field_targeted():
    st = {"T": jnp.zeros((4, 5)), "S": jnp.zeros((4, 5)),
          "turb_k": jnp.zeros(3)}

    def poisoned(seed):
        plan = chaos.FaultPlan([chaos.Fault("sim.state", "poison_nan",
                                            step=2, field="T")], seed=seed)
        with chaos.active(plan):
            out = chaos.site("sim.state", st, step=2)
        return out, plan
    o1, p1 = poisoned(0)
    o2, _ = poisoned(0)
    o3, _ = poisoned(1)
    assert tree_equal(o1, o2)                        # same seed, same cell
    assert np.isnan(np.asarray(o1["T"])).sum() == 1  # exactly one element
    assert not np.isnan(np.asarray(o1["S"])).any()   # exact-name match:
    assert not np.isnan(np.asarray(o1["turb_k"])).any()   # T != turb_k
    i1 = np.flatnonzero(np.isnan(np.asarray(o1["T"]).ravel()))
    i3 = np.flatnonzero(np.isnan(np.asarray(o3["T"]).ravel()))
    assert p1.log and "T" in p1.log[0]["detail"]
    # step gating: nothing fires off-step
    plan = chaos.FaultPlan([chaos.Fault("sim.state", "poison_nan",
                                        step=2, field="T")])
    with chaos.active(plan):
        out = chaos.site("sim.state", st, step=1)
    assert tree_equal(out, st) and plan.log == []
    del i1, i3   # (different seeds may or may not collide; determinism is
    #              what matters and is asserted above)


def test_corrupt_leaf_and_latest_injectors(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    ck.save(1, demo_tree(), blocking=True)
    plan = chaos.FaultPlan([
        chaos.Fault("checkpoint.saved", "truncate", step=2, field="a"),
        chaos.Fault("checkpoint.saved", "stale_latest", step=3)])
    with chaos.active(plan):
        ck.save(2, demo_tree(), blocking=True)
        ck.save(3, demo_tree(), blocking=True)
    assert any("checksum" in p or "unreadable" in p for p in ck.verify(2))
    assert open(tmp_path / "latest").read().strip() == "step_000000001"
    # hardened latest_step ignores the stale pointer; restore skips step 2
    assert ck.latest_step() == 3
    assert 2 not in ck.intact_steps()


# ---------------------------------------------------------------------------
# halo-exchange payload corruption (trace-time site)
# ---------------------------------------------------------------------------
def test_halo_payload_chaos_site():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed import halo

    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    t = halo.HaloTables(send=(jnp.arange(2, dtype=jnp.int32),),
                        recv=(jnp.asarray([2, 3], jnp.int32),),
                        offsets=(0,), n_devices=1, axes=("x",))
    x = jnp.arange(1.0, 5.0)[None, :]           # (1 device, 4 slots)

    def f(xs):
        return halo.exchange(xs[0], t)[None]
    run = lambda: jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"),
                                    out_specs=P("x"), check_rep=False))(x)
    clean = np.asarray(run())
    np.testing.assert_array_equal(clean[0], [1.0, 2.0, 1.0, 2.0])

    plan = chaos.FaultPlan([chaos.Fault("halo.payload", "halo_nan")])
    with chaos.active(plan):                    # armed during TRACING
        poisoned = np.asarray(jax.jit(shard_map(
            lambda xs: halo.exchange(xs[0] * 1.0, t)[None], mesh=mesh,
            in_specs=P("x"), out_specs=P("x"), check_rep=False))(x))
    assert np.isnan(poisoned[0, 2:]).all()      # halo slots poisoned
    np.testing.assert_array_equal(poisoned[0, :2], [1.0, 2.0])  # owned intact
    assert plan.log[0]["kind"] == "halo_nan"


# ---------------------------------------------------------------------------
# elastic restore (satellite 4)
# ---------------------------------------------------------------------------
_SUBPROC_ENV = {"PYTHONPATH": "src", "HOME": "/root",
                "PATH": "/usr/bin:/bin", "JAX_ENABLE_X64": "1",
                # without this jax probes for TPUs at backend init and hangs
                "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}


def test_elastic_restore_1_to_8_devices(tmp_path):
    """Save on THIS 1-device process; restore onto 8 spoofed devices with a
    sharded layout; global array must be bitwise identical."""
    ck = Checkpointer(str(tmp_path))
    x = jnp.arange(64.0).reshape(8, 8)
    ck.save(7, {"x": x}, blocking=True)
    assert ck.verify(7) == []
    script = f'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import Checkpointer
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
sh = NamedSharding(mesh, P("data", None))
ck = Checkpointer({str(tmp_path)!r})
out = ck.restore({{"x": jnp.zeros((8, 8))}}, shardings={{"x": sh}})
assert len(out["x"].sharding.device_set) == 8, out["x"].sharding
assert np.array_equal(np.asarray(out["x"]),
                      np.arange(64.0).reshape(8, 8)), "values differ"
print("RESTORED_8DEV")
'''
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300,
                         env=_SUBPROC_ENV, cwd="/root/repo")
    assert "RESTORED_8DEV" in res.stdout, res.stdout + res.stderr


def test_elastic_restore_8_to_1_devices(tmp_path):
    """Save sharded over 8 spoofed devices; restore in THIS 1-device
    process; the manifest-verified global array is bitwise identical."""
    script = f'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import Checkpointer
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh, P("data", None)))
ck = Checkpointer({str(tmp_path)!r})
ck.save(5, {{"x": x}}, blocking=True)
assert ck.verify(5) == [], ck.verify(5)
print("SAVED_8DEV")
'''
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300,
                         env=_SUBPROC_ENV, cwd="/root/repo")
    assert "SAVED_8DEV" in res.stdout, res.stdout + res.stderr
    ck = Checkpointer(str(tmp_path))
    meta = ck.manifest(5)
    assert meta["leaves"]["x"]["shape"] == [8, 8]   # GLOBAL shape on disk
    out = ck.restore({"x": jnp.zeros((8, 8))})
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.arange(64.0).reshape(8, 8))


def test_elastic_reshard_chaos_site(tmp_path):
    """The runner.restore_shardings site swaps shardings at recovery time
    (elastic restore onto a different layout), bitwise-preserving."""
    from jax.sharding import SingleDeviceSharding
    ck = Checkpointer(str(tmp_path))
    x = jnp.arange(12.0).reshape(3, 4)
    ck.save(2, {"x": x}, blocking=True)

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), emit_metrics=False)
    runner = SimulationRunner(lambda c: None, object(), cfg)
    sh = {"x": SingleDeviceSharding(jax.devices()[0])}
    plan = chaos.FaultPlan([chaos.Fault("runner.restore_shardings",
                                        "reshard", args={"shardings": sh})])
    with chaos.active(plan):
        state, step = runner._recover({"x": jnp.zeros((3, 4))}, None, 0)
    assert step == 2 and plan.log[0]["kind"] == "reshard"
    assert state["x"].sharding == sh["x"]
    np.testing.assert_array_equal(np.asarray(state["x"]), np.asarray(x))


# ---------------------------------------------------------------------------
# runner semantics (satellites 2 + 3)
# ---------------------------------------------------------------------------
class _Dataset:
    def batch_at(self, step):
        return {"x": jnp.asarray(float(step))}


def test_train_runner_cold_restore_from_start(tmp_path):
    """A failure BEFORE the first checkpoint must restart from the caller's
    start snapshot (counted as a cold restore), not silently retry the
    in-memory state."""
    seen = []
    failed = {"done": False}

    def step_fn(state, batch):
        s = int(state["step"])
        seen.append(s)
        if s == 2 and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("injected failure before first checkpoint")
        return ({"step": state["step"] + 1,
                 "acc": state["acc"] + batch["x"]}, {"loss": 1.0})

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=100,
                       max_retries=2, emit_metrics=False,
                       backoff_base_s=0.0)
    runner = TrainRunner(step_fn, _Dataset(), cfg)
    out = runner.run({"step": jnp.asarray(0), "acc": jnp.asarray(0.0)},
                     n_steps=4, resume=False)
    assert runner.stats["cold_restores"] == 1
    assert seen == [0, 1, 2, 0, 1, 2, 3]        # restarted from scratch
    assert int(out["step"]) == 4
    assert float(out["acc"]) == sum(range(4))   # deterministic re-run


def test_signal_handlers_restored_after_run(tmp_path):
    """The runner's SIGTERM/SIGINT handlers must not leak past run()
    (previously they leaked into pytest and subsequent code)."""
    sentinel = lambda signum, frame: None
    prev_term = signal.getsignal(signal.SIGTERM)
    prev_int = signal.getsignal(signal.SIGINT)
    try:
        signal.signal(signal.SIGTERM, sentinel)
        cfg = RunnerConfig(checkpoint_dir=str(tmp_path),
                           emit_metrics=False)
        runner = TrainRunner(
            lambda s, b: (s, {"loss": 1.0}), _Dataset(), cfg)
        runner.run({"s": jnp.asarray(0)}, n_steps=2, resume=False)
        assert signal.getsignal(signal.SIGTERM) is sentinel
        assert signal.getsignal(signal.SIGINT) is prev_int
        # ... even when the run dies on an exhausted failure
        bad = TrainRunner(lambda s, b: (_ for _ in ()).throw(
            RuntimeError("boom")), _Dataset(),
            dataclasses.replace(cfg, max_retries=0, backoff_base_s=0.0))
        with pytest.raises(RuntimeError, match="boom"):
            bad.run({"s": jnp.asarray(0)}, n_steps=2, resume=False)
        assert signal.getsignal(signal.SIGTERM) is sentinel
    finally:
        signal.signal(signal.SIGTERM, prev_term)
        signal.signal(signal.SIGINT, prev_int)


def test_runner_save_failure_is_retried_not_silent(tmp_path):
    """An async save failure surfaces at the next save and is retried
    synchronously — the run keeps its checkpoint cadence."""
    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2,
                       max_retries=2, emit_metrics=False, backoff_base_s=0.0)
    runner = TrainRunner(
        lambda s, b: ({"step": s["step"] + 1}, {"loss": 1.0}),
        _Dataset(), cfg)
    plan = chaos.FaultPlan([chaos.Fault("checkpoint.write", "io_error",
                                        step=2)])
    with chaos.active(plan):
        out = runner.run({"step": jnp.asarray(0)}, n_steps=6, resume=False)
    assert int(out["step"]) == 6
    assert runner.stats["ckpt_failures"] == 1
    assert runner.ckpt.latest_step() == 6       # cadence recovered


# ---------------------------------------------------------------------------
# SimulationRunner: synthetic ladder mechanics (fast, no ocean step)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _ToyCfg:
    dt: float = 10.0


def test_sim_ladder_engages_and_rewidens(tmp_path):
    """Deterministic early-phase failure at full dt: blind retry loops, the
    ladder degrades to dt/2, rides out the rough phase, then re-widens."""
    def factory(cfg):
        def fn(state):
            n = int(state["n"])
            if cfg.dt == 10.0 and n < 2:
                raise RuntimeError(f"synthetic blow-up at n={n}")
            return {"n": state["n"] + 1}, {"nonfinite": False,
                                           "cfl_2d": 0.2}
        return fn

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2,
                       max_retries=3, emit_metrics=False, backoff_base_s=0.0)
    ladder = LadderConfig(dt_factor=0.5, max_rungs=2, recover_steps=3,
                          cfl_ok=0.8)
    runner = SimulationRunner(factory, _ToyCfg(), cfg, ladder=ladder)
    out = runner.run({"n": jnp.asarray(0)}, n_steps=6, resume=False)
    assert int(out["n"]) == 6
    # retry 1: plain restore at full dt (fails again); retry 2: rung 1
    assert runner.stats["retries"] == 2
    assert runner.stats["cold_restores"] == 2   # no checkpoint existed yet
    assert runner.stats["ladder_engagements"] == 1
    assert runner.stats["ladder_transitions"] == 2   # down once, up once
    assert runner.rung == 0                          # re-widened


def test_sim_ladder_disabled_is_blind_retry(tmp_path):
    def factory(cfg):
        def fn(state):
            if cfg.dt == 10.0:
                raise RuntimeError("deterministic blow-up")
            return {"n": state["n"] + 1}, {"nonfinite": False}
        return fn

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), max_retries=3,
                       emit_metrics=False, backoff_base_s=0.0)
    runner = SimulationRunner(factory, _ToyCfg(), cfg,
                              ladder=LadderConfig(max_rungs=0))
    with pytest.raises(RuntimeError, match="deterministic blow-up"):
        runner.run({"n": jnp.asarray(0)}, n_steps=4, resume=False)
    assert runner.stats["retries"] == 4          # exhausted, no escalation


# ---------------------------------------------------------------------------
# the ocean chaos matrix (bitwise recovery) + the CFL blow-up ladder
# ---------------------------------------------------------------------------
N_STEPS = 6
_FNS = {}


@pytest.fixture(scope="module")
def ocean_case():
    return sim_campaign.build_case(nx=4, ny=3, nl=4)


def _factory_for(case):
    """step_factory with a per-dt jit cache so the matrix scenarios reuse
    one compiled step."""
    def factory(cfg):
        key = (float(cfg.dt), float(cfg.nu_v_bg))
        if key not in _FNS:
            _FNS[key] = jax.jit(lambda s: obs_diag.step_with_diagnostics(
                case.geom, case.vg, cfg, s))
        return _FNS[key]
    return factory


def _run_ocean(case, tmp_path, name, plan, resume=False, n=N_STEPS):
    cfg = RunnerConfig(checkpoint_dir=str(tmp_path / name),
                       checkpoint_every=2, max_retries=3,
                       emit_metrics=False, backoff_base_s=0.0)
    runner = SimulationRunner(
        _factory_for(case), case.cfg, cfg,
        policy=obs_diag.MonitorPolicy(cfl_max=1.0, on_violation="halt"))
    ctx = chaos.active(plan) if plan is not None else _Null()
    with ctx:
        out = runner.run(case.state, n, resume=resume)
    return out, runner


class _Null:
    def __enter__(self):
        return None

    def __exit__(self, *e):
        return False


@pytest.fixture(scope="module")
def ocean_baseline(ocean_case, tmp_path_factory):
    out, _ = _run_ocean(ocean_case, tmp_path_factory.mktemp("base"),
                        "baseline", plan=None)
    return out


def test_chaos_matrix_nan_poison_bitwise(ocean_case, ocean_baseline,
                                         tmp_path):
    plan = chaos.FaultPlan([chaos.Fault("sim.state", "poison_nan",
                                        step=N_STEPS - 1, field="T")])
    out, runner = _run_ocean(ocean_case, tmp_path, "nan", plan)
    assert len(plan.log) == 1
    assert runner.stats["retries"] == 1
    assert runner.rung == 0                      # transient: no degradation
    assert tree_equal(out, ocean_baseline)


def test_chaos_matrix_corrupt_checkpoint_bitwise(ocean_case, ocean_baseline,
                                                 tmp_path):
    metrics.reset()
    plan = chaos.FaultPlan(
        [chaos.Fault("checkpoint.saved", "truncate", step=4),
         chaos.Fault("sim.state", "poison_inf", step=N_STEPS - 1,
                     field="ux")])
    out, runner = _run_ocean(ocean_case, tmp_path, "corrupt", plan)
    skipped = metrics.default().snapshot()["counter"].get(
        "checkpoint.corrupt_skipped", 0)
    assert skipped >= 1                          # fell back past step 4
    assert tree_equal(out, ocean_baseline)
    metrics.reset()


def test_chaos_matrix_preemption_bitwise(ocean_case, ocean_baseline,
                                         tmp_path):
    plan = chaos.FaultPlan([chaos.Fault("runner.step", "preempt",
                                        step=N_STEPS - 2)])
    out1, runner1 = _run_ocean(ocean_case, tmp_path, "preempt", plan)
    assert runner1.stats["preempted"]
    saved = runner1.ckpt.latest_step()
    assert saved == N_STEPS - 2                  # blocking save on SIGTERM
    out, runner2 = _run_ocean(ocean_case, tmp_path, "preempt", plan=None,
                              resume=True)
    assert runner2.stats["steps"] == 2           # only the preempted tail
    assert tree_equal(out, ocean_baseline)


def test_chaos_matrix_save_thread_failure_bitwise(ocean_case, ocean_baseline,
                                                  tmp_path):
    plan = chaos.FaultPlan([chaos.Fault("checkpoint.write", "io_error",
                                        step=2)])
    out, runner = _run_ocean(ocean_case, tmp_path, "savefail", plan)
    assert runner.stats["ckpt_failures"] == 1
    assert runner.stats["retries"] == 0          # never lost sim progress
    assert tree_equal(out, ocean_baseline)


def test_cfl_blowup_recovers_via_dt_ladder(ocean_case, tmp_path):
    """Forced deterministic CFL blow-up (dt=80 on this mesh diverges in one
    step): blind restore-and-retry provably fails; the dt ladder halves dt,
    finishes the run, and reports the engagement through obs.metrics."""
    metrics.reset()
    blow = sim_campaign.build_case(nx=4, ny=3, nl=4, dt=80.0)
    policy = lambda: obs_diag.MonitorPolicy(cfl_max=1.0, on_violation="halt")
    cfg = lambda d: RunnerConfig(checkpoint_dir=str(tmp_path / d),
                                 checkpoint_every=2, max_retries=3,
                                 backoff_base_s=0.0)

    # the OLD behaviour (no ladder): restores the same state, re-runs the
    # same step, fails identically until retries are exhausted
    blind = SimulationRunner(_factory_for(blow), blow.cfg, cfg("blind"),
                             policy=policy(),
                             ladder=LadderConfig(max_rungs=0))
    with pytest.raises(obs_diag.MonitorHalt):
        blind.run(blow.state, 4, resume=False)
    assert blind.stats["retries"] == 4

    # the ladder: retry 2 drops to dt=40 (CFL ~0.34) and the run finishes
    ladder = LadderConfig(dt_factor=0.5, max_rungs=2, recover_steps=64)
    runner = SimulationRunner(_factory_for(blow), blow.cfg, cfg("ladder"),
                              policy=policy(), ladder=ladder)
    out = runner.run(blow.state, 4, resume=False)
    assert runner.stats["ladder_engagements"] >= 1
    assert runner.rung == 1
    assert runner.stats["steps"] == 4
    assert float(out.time) == pytest.approx(4 * 40.0)    # ran at dt/2
    snap = metrics.default().snapshot()["counter"]
    assert snap.get("sim.ladder.transitions{direction=down}", 0) >= 1
    d = obs_diag.to_dict(obs_diag.compute(blow.geom, blow.vg,
                                          blow.cfg.with_recovery(0.5), out))
    assert not d["nonfinite"] and d["cfl_2d"] < 1.0
    metrics.reset()


def test_with_recovery_scales_dt_and_viscosity():
    from repro.core import stepper
    cfg = stepper.OceanConfig(dt=60.0, m_2d=20, nu_v_bg=1e-4, kappa_v_bg=1e-5)
    r = cfg.with_recovery(dt_factor=0.5, visc_factor=10.0)
    assert r.dt == 30.0 and r.m_2d == 20        # dt_2d halves consistently
    assert r.nu_v_bg == pytest.approx(1e-3)
    assert r.kappa_v_bg == pytest.approx(1e-4)
    assert cfg.dt == 60.0                        # original untouched
