"""Backend dispatch tests: the cell-layout Pallas solvers wired into the
stepper hot path must be selectable, pad ragged column counts, and match the
SoA reference end-to-end (ISSUE 1 tentpole)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dg2d, geometry, layout, mesh2d, stepper, vertical
from repro.core.extrusion import VGrid
from repro.kernels import cell_transpose, column_solve, dispatch, ops, ref

F64 = jnp.float64


def rand(rng, shape, dtype=np.float64):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# dispatch resolution
# ---------------------------------------------------------------------------
def test_resolve_auto_cpu():
    bk = dispatch.resolve(None)
    plat = jax.default_backend()
    if plat == "cpu":
        assert bk is dispatch.Backend.PALLAS_INTERPRET
        assert dispatch.interpret_default() is True
    elif plat == "tpu":
        assert bk is dispatch.Backend.PALLAS
        assert dispatch.interpret_default() is False
    else:                                            # GPU: kernels are
        assert bk is dispatch.Backend.REF            # TPU-only, fall back
    assert dispatch.resolve("auto") is bk
    assert dispatch.resolve("kernel") is bk          # legacy ops.py name


def test_resolve_explicit():
    assert dispatch.resolve("ref") is dispatch.Backend.REF
    assert dispatch.resolve("pallas") is dispatch.Backend.PALLAS
    assert dispatch.resolve(dispatch.Backend.REF) is dispatch.Backend.REF
    assert dispatch.interpret_flag(dispatch.Backend.PALLAS) is False
    assert dispatch.interpret_flag(dispatch.Backend.PALLAS_INTERPRET) is True
    with pytest.raises(ValueError):
        dispatch.resolve("no_such_backend")


# ---------------------------------------------------------------------------
# ragged column counts: pad + slice in every cell kernel
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=6)
@given(C=st.sampled_from([1, 60, 127, 129, 200]))
def test_block_thomas_cell_ragged(C):
    rng = np.random.default_rng(C)
    nl, k = 4, 2
    mk = lambda: rand(rng, (nl, 6, 6, C)) * 0.1
    lo = mk().at[0].set(0.0)
    up = mk().at[-1].set(0.0)
    dg = mk() + 2.0 * jnp.eye(6, dtype=F64)[None, :, :, None]
    b = rand(rng, (nl, 6, k, C))
    out = column_solve.block_thomas_cell(lo, dg, up, b, interpret=True)
    exp = ref.block_thomas_cell(lo, dg, up, b)
    assert out.shape == b.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-10, atol=1e-10)


@settings(deadline=None, max_examples=4)
@given(C=st.sampled_from([1, 60, 129]))
def test_matrix_free_ragged(C):
    rng = np.random.default_rng(C + 17)
    nl = 3
    F = rand(rng, (nl * 6, C))
    area = jnp.abs(rand(rng, (1, C))) + 0.5
    bc = rand(rng, (3, C))
    from repro.kernels import matrix_free
    out_r = matrix_free.solve_r_cell(F, area, bc, interpret=True)
    np.testing.assert_allclose(np.asarray(out_r),
                               np.asarray(ref.solve_r_cell(F, area, bc)),
                               rtol=1e-10, atol=1e-12)
    out_w = matrix_free.solve_w_cell(F, area, bc, interpret=True)
    np.testing.assert_allclose(np.asarray(out_w),
                               np.asarray(ref.solve_w_cell(F, area, bc)),
                               rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# layout round-trips for non-multiple-of-128 nt
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=8)
@given(nl=st.sampled_from([1, 4]), nt=st.sampled_from([1, 60, 127, 128, 129, 300]))
def test_layout_roundtrip_ragged(nl, nt):
    x = jnp.arange(nl * 6 * nt, dtype=F64).reshape(nl, 6, nt)
    c = layout.soa_to_cell(x)
    assert c.shape == (layout.num_cells(nt), nl * 6, layout.CELL)
    back = layout.cell_to_soa(c, nl, 6, nt)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@settings(deadline=None, max_examples=8)
@given(nl=st.sampled_from([1, 4]), nt=st.sampled_from([1, 60, 127, 128, 129, 300]))
def test_cell_transpose_kernel_roundtrip_ragged(nl, nt):
    """The Pallas transpose pads ragged nt and must agree with the jnp
    layout transform bit-for-bit both ways."""
    x = jnp.arange(nl * 6 * nt, dtype=F64).reshape(nl, 6, nt)
    c = cell_transpose.soa_to_cell(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(c),
                                  np.asarray(layout.soa_to_cell(x)))
    back = cell_transpose.cell_to_soa(c, nt=nt, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_blocks_cell_roundtrip():
    rng = np.random.default_rng(2)
    nl, nt = 3, 200
    blk = rand(rng, (nl, 6, 6, nt))
    c = layout.blocks_to_cell(blk)
    assert c.shape == (layout.num_cells(nt), nl, 6, 6, layout.CELL)
    np.testing.assert_array_equal(
        np.asarray(layout.cell_to_blocks(c, nt)), np.asarray(blk))


# ---------------------------------------------------------------------------
# SoA-level dispatch wrappers vs the core solvers (real mesh, ragged nt)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_geom():
    m = mesh2d.rect_mesh(6, 5, 2.0, 1.5, jitter=0.2, seed=1)   # nt=60
    return geometry.geom2d_from_mesh(m, dtype=F64)


def test_ops_solve_r_dispatch(small_geom):
    geom = small_geom
    nl, nt = 5, geom.nt
    rng = np.random.default_rng(3)
    F = rand(rng, (2, nl, 6, nt))            # leading component axis folded
    rs = rand(rng, (2, 3, nt))
    exp = vertical.solve_r(geom, F, rs)
    out = ops.solve_r(geom, F, rs, backend="pallas_interpret")
    assert out.shape == exp.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(ops.solve_r(geom, F, rs, backend="ref")),
        np.asarray(exp), rtol=1e-12, atol=1e-13)


def test_ops_solve_w_dispatch(small_geom):
    geom = small_geom
    nl, nt = 5, geom.nt
    rng = np.random.default_rng(4)
    F = rand(rng, (nl, 6, nt))
    exp = vertical.solve_w(geom, F)          # impermeable floor (None)
    out = ops.solve_w(geom, F, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-10, atol=1e-12)
    wf = rand(rng, (3, nt))
    np.testing.assert_allclose(
        np.asarray(ops.solve_w(geom, F, wf, backend="pallas_interpret")),
        np.asarray(vertical.solve_w(geom, F, wf)), rtol=1e-10, atol=1e-12)


def test_ops_block_thomas_dispatch():
    rng = np.random.default_rng(5)
    nl, nt, k = 4, 60, 2
    mk = lambda: rand(rng, (nl, 6, 6, nt)) * 0.1
    lo = mk().at[0].set(0.0)
    up = mk().at[-1].set(0.0)
    dg = mk() + 2.0 * jnp.eye(6, dtype=F64)[None, :, :, None]
    blocks = vertical.Blocks(lo=lo, dg=dg, up=up)
    rhs = rand(rng, (k, nl, 6, nt))
    exp = vertical.block_thomas_solve(blocks, rhs)
    out = ops.block_thomas(blocks, rhs, backend="pallas_interpret")
    assert out.shape == exp.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-9, atol=1e-11)


# ---------------------------------------------------------------------------
# end-to-end: full stepper step, Pallas cell-layout path vs SoA reference
# ---------------------------------------------------------------------------
def _step_setup():
    m = mesh2d.rect_mesh(4, 3, 2000.0, 1500.0, jitter=0.2, seed=3)  # nt=24
    geom = geometry.geom2d_from_mesh(m, dtype=F64)
    b = jnp.full((3, m.nt), 20.0, F64)
    vg = VGrid(b=b, nl=3)
    st = stepper.init_state(geom, vg, dtype=F64)
    eta0 = (0.05 * jnp.cos(jnp.pi * geom.node_x / 2000.0)
            * jnp.cos(jnp.pi * geom.node_y / 1500.0))
    Tf = 10.0 + 2.0 * jnp.exp(-((geom.node_x - 800.0) ** 2
                                + (geom.node_y - 600.0) ** 2) / 4e5)
    T0 = jnp.broadcast_to(jnp.concatenate([Tf, Tf])[None], st.T.shape)
    st = stepper.OceanState(
        ext=dg2d.State2D(eta0, st.ext.qx, st.ext.qy), ux=st.ux, uy=st.uy,
        T=T0, S=st.S, turb_k=st.turb_k, turb_eps=st.turb_eps, nu_t=st.nu_t,
        kappa_t=st.kappa_t, time=st.time)
    cfg = stepper.OceanConfig(nl=3, dt=20.0, m_2d=4, use_gls=True,
                              backend="ref")
    return geom, vg, cfg, st


def test_stepper_backend_equivalence():
    """Implicit momentum/tracer + r/w solves through the Pallas cell-layout
    kernels must reproduce the SoA reference step to f64 roundoff."""
    geom, vg, cfg_ref, st = _step_setup()
    cfg_pal = dataclasses.replace(cfg_ref, backend="pallas_interpret")
    a = stepper.step(geom, vg, cfg_ref, st)
    b = stepper.step(geom, vg, cfg_pal, st)
    for name in ("ux", "uy", "T", "S"):
        xa = np.asarray(getattr(a, name))
        xb = np.asarray(getattr(b, name))
        scale = max(np.abs(xa).max(), 1.0)
        assert np.abs(xa - xb).max() < 1e-11 * scale, (
            name, np.abs(xa - xb).max())
    np.testing.assert_allclose(np.asarray(a.ext.eta), np.asarray(b.ext.eta),
                               rtol=0, atol=1e-12)
    # the step did something (the equivalence is not 0 == 0)
    assert np.abs(np.asarray(a.ux)).max() > 1e-10


def test_state_cell_roundtrip():
    geom, vg, cfg, st = _step_setup()
    cells = stepper.state_to_cell(st, backend="pallas_interpret")
    assert cells["T"].shape == (1, 3 * 6, 128)
    back = stepper.state_from_cell(st, cells, geom.nt,
                                   backend="pallas_interpret")
    for name in ("ux", "uy", "T", "S"):
        np.testing.assert_array_equal(np.asarray(getattr(back, name)),
                                      np.asarray(getattr(st, name)))
