"""Per-architecture smoke tests: reduced same-family configs, one forward/
train step + one decode step on CPU, asserting shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro.configs import ALL_ARCHS, get_arch, reduce_arch
from repro.models.model import Model, count_params

ARCHS = sorted(ALL_ARCHS)
B, T = 2, 32


def make_batch(model, rng):
    a = model.arch
    batch = {"tokens": jax.random.randint(rng, (B, T), 0, a.vocab)}
    if a.frontend == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, a.n_patches, a.d_model), jnp.float32)
    if a.frontend == "audio":
        batch["frame_embeds"] = jax.random.normal(
            rng, (B, T, a.d_model), jnp.float32)
    batch["labels"] = jax.random.randint(
        jax.random.fold_in(rng, 1), (B, T), 0, a.vocab)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_grad(name):
    arch = reduce_arch(get_arch(name))
    model = Model(arch, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(model, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), name
    gnorm = jax.tree_util.tree_reduce(
        lambda a, b: a + float(jnp.abs(b).sum()), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0, name
    logits, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, T, arch.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if not ALL_ARCHS[n].encoder_only])
def test_decode_step(name):
    arch = reduce_arch(get_arch(name))
    model = Model(arch, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, T)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tok, jnp.int32(0))
    logits2, cache = step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (B, arch.vocab)
    assert bool(jnp.isfinite(logits).all()) and bool(
        jnp.isfinite(logits2).all()), name


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if not ALL_ARCHS[n].encoder_only])
def test_decode_matches_prefill(name):
    """Token-by-token decode must reproduce the full-sequence forward
    (validates KV caches, RoPE positions, mamba/rwkv recurrent states)."""
    arch = reduce_arch(get_arch(name))
    model = Model(arch, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(2)
    toks = jax.random.randint(rng, (B, 8), 0, arch.vocab)
    batch = {"tokens": toks}
    if arch.frontend == "vlm":
        # patch embeds replace the first n_patches positions; zero for parity
        batch["patch_embeds"] = jnp.zeros((B, arch.n_patches, arch.d_model))
    logits_full, _ = jax.jit(model.forward)(params, batch)
    if arch.frontend == "vlm":
        pytest.skip("vlm decode parity needs patch prefill (covered by shapes)")
    cache = model.init_cache(B, 8)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(8):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_count_params_moe_active():
    arch = get_arch("phi3.5-moe-42b-a6.6b")
    model = Model(arch)
    total, active = count_params(model)
    # 42B-ish total, 6.6B-ish active (pool annotation)
    assert 35e9 < total < 50e9, total
    assert 5e9 < active < 9e9, active


def test_count_params_dense_scales():
    total, active = count_params(Model(get_arch("mistral-large-123b")))
    assert 110e9 < total < 135e9, total
    assert total == active
    t2, _ = count_params(Model(get_arch("olmo-1b")))
    assert 0.9e9 < t2 < 1.6e9, t2
