"""End-to-end system tests.

1. Ocean: a short full-physics simulation stays stable and conservative.
2. LM: a tiny model trains end-to-end through the production stack
   (sharded AdamW + runner) and the loss decreases.
3. Dry-run: the launcher lowers + compiles cells on a spoofed multi-device
   mesh and produces roofline records (subprocess; the full 512-device
   sweep lives in experiments/dryrun, this guards the machinery).
4. Roofline parser: unit guard on synthetic HLO (trip-count expansion,
   collective classification).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_ocean_end_to_end():
    from repro.core import geometry, mesh2d, stepper, vertical
    from repro.core.extrusion import VGrid, layer_geometry
    m = mesh2d.rect_mesh(6, 4, 3000.0, 2000.0, jitter=0.2, seed=11)
    geom = geometry.geom2d_from_mesh(m)
    b = jnp.full((3, m.nt), 25.0)
    vg = VGrid(b=b, nl=4)
    cfg = stepper.OceanConfig(nl=4, dt=30.0, m_2d=10, use_gls=True,
                              eos_kind="jackett", coriolis_f=1e-4)
    st = stepper.init_state(geom, vg, T0=15.0, S0=35.0)
    Tf = 15.0 + 2.0 * jnp.tanh((1500.0 - geom.node_x) / 300.0)
    T = jnp.broadcast_to(jnp.concatenate([Tf, Tf])[None], st.T.shape)
    st = stepper.OceanState(ext=st.ext, ux=st.ux, uy=st.uy, T=T, S=st.S,
                            turb_k=st.turb_k, turb_eps=st.turb_eps,
                            nu_t=st.nu_t, kappa_t=st.kappa_t, time=st.time)
    vge0 = layer_geometry(vg, st.ext.eta)
    heat0 = float(vertical.mass_apply3d(geom, vge0.jz, st.T).sum())
    step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
    for _ in range(8):
        st = step(st)
    assert bool(jnp.isfinite(st.ux).all())
    assert float(jnp.abs(st.ux).max()) > 1e-7          # front slumps
    vge = layer_geometry(vg, st.ext.eta)
    heat = float(vertical.mass_apply3d(geom, vge.jz, st.T).sum())
    assert abs(heat - heat0) < 1e-5 * abs(heat0)       # heat conserved


def test_lm_end_to_end_loss_decreases(tmp_path):
    import dataclasses
    from repro.configs import get_arch
    from repro.data.pipeline import TokenDataset
    from repro.models.model import Model
    from repro.optim import adamw
    arch = dataclasses.replace(get_arch("olmo-1b"), n_layers=2, d_model=128,
                               n_heads=4, n_kv=4, d_ff=512, vocab=512,
                               remat=False)
    model = Model(arch, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=2e-3, weight_decay=0.0)
    ds = TokenDataset(vocab=512, seq_len=64, global_batch=8, seed=1)

    @jax.jit
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt = adamw.update(grads, opt, params, cfg)
        return params, opt, loss

    losses = []
    for s in range(40):
        params, opt, loss = train_step(params, opt, ds.batch_at(s))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.05, losses[:3]


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch import dryrun
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh(2, 4)
for arch, shape in [("olmo-1b", "train_4k"), ("rwkv6-3b", "decode_32k")]:
    lowered, aux = dryrun.lower_cell(arch, shape, mesh)
    rec = dryrun.compile_and_analyze(lowered, aux, mesh, verbose=False)
    ro = rec["roofline"]
    assert rec["memory"]["peak_per_device"] > 0
    assert ro["memory_s"] > 0
    assert ro["dominant"] in ("compute", "memory", "collective")
    if shape == "train_4k":
        assert ro["compute_s"] > 0 and 0.05 < ro["useful_ratio"] <= 1.2
print("DRYRUN_OK")
'''
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1500,
                         env={"PYTHONPATH": "src", "HOME": "/root",
                              "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo")
    assert "DRYRUN_OK" in res.stdout, res.stdout[-2000:] + res.stderr[-2000:]


def test_roofline_parser_on_synthetic_hlo():
    """The HLO parser must expand while-loop trip counts and classify
    collectives (unit-level guard for the roofline methodology)."""
    from repro.roofline import analysis
    hlo = """
HloModule test

%body.1 (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[128,128] get-tuple-element(%p), index=1
  %d = f32[128,128] dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128] all-reduce(%d), to_apply=%sum.1
  ROOT %t = (s32[], f32[128,128]) tuple(%g0, %ar)
}

%cond.1 (p2: (s32[], f32[128,128])) -> pred[] {
  %p2 = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,128]) -> f32[128,128] {
  %x = f32[128,128] parameter(0)
  %init = (s32[], f32[128,128]) tuple(%c0, %x)
  %w = (s32[], f32[128,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[128,128] get-tuple-element(%w), index=1
}
"""
    st = analysis.analyze_hlo_text(hlo)
    # 12 iterations x (2 * 128^3) flops
    assert st.flops == 12 * 2 * 128 ** 3, st.flops
    assert st.n_collectives == 12
    # all-reduce counted at 2x buffer size
    assert st.coll_bytes == 12 * 2 * 128 * 128 * 4
    assert "all-reduce" in st.coll_by_kind
