"""External (2D barotropic) mode property tests.

The key physical invariants of the DG discretisation:
  * well-balancedness (lake at rest over varying bathymetry),
  * exact discrete mass conservation in a closed basin,
  * correct gravity-wave dynamics (standing-wave period),
  * energy dissipation (LF fluxes never create energy).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dg2d, geometry, mesh2d
from repro.core.dg2d import Forcing2D, State2D

G_ = geometry.G_GRAV


def make(nx=12, ny=10, lx=1000.0, ly=800.0, jitter=0.2, depth=20.0,
         shelf=False):
    m = mesh2d.rect_mesh(nx, ny, lx, ly, jitter=jitter, seed=2)
    geom = geometry.geom2d_from_mesh(m)
    if shelf:
        bfun = mesh2d.shelf_bathymetry(depth * 0.3, depth, lx)
        b = jnp.asarray(np.stack([bfun(np.stack(
            [np.asarray(geom.node_x[i]), np.asarray(geom.node_y[i])], 1))
            for i in range(3)]), dtype=jnp.float32)
    else:
        b = jnp.full((3, m.nt), depth)
    return m, geom, b


def zeros_state(nt):
    z = jnp.zeros((3, nt))
    return State2D(z, z, z)


def total_mass(geom, eta):
    return float(geometry.mass_apply(geom, eta).sum())


def total_energy(geom, b, st):
    H = st.eta + b
    e = 0.5 * G_ * st.eta ** 2 + 0.5 * (st.qx ** 2 + st.qy ** 2) / H
    # integrate P1-interpolated energy density
    return float(geometry.mass_apply(geom, e).sum())


def test_lake_at_rest_flat():
    m, geom, b = make()
    st = zeros_state(m.nt)
    r = dg2d.external_rhs(geom, b, st)
    for f in (r.eta, r.qx, r.qy):
        np.testing.assert_allclose(np.asarray(f), 0.0, atol=1e-6)


def test_lake_at_rest_shelf():
    """Well-balancedness: varying bathymetry, eta = 0, Q = 0 stays at rest."""
    m, geom, b = make(shelf=True)
    st = zeros_state(m.nt)
    r = dg2d.external_rhs(geom, b, st)
    # scale: g*H*grad(eta) terms would be O(g*20/1000) ~ 0.2 if unbalanced
    for f in (r.eta, r.qx, r.qy):
        np.testing.assert_allclose(np.asarray(f), 0.0, atol=1e-5)


def test_mass_conservation_closed_basin():
    m, geom, b = make(shelf=True)
    key = jax.random.PRNGKey(0)
    eta = 0.1 * jax.random.normal(key, (3, m.nt))
    qx = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (3, m.nt))
    qy = 0.5 * jax.random.normal(jax.random.fold_in(key, 2), (3, m.nt))
    st = State2D(eta, qx, qy)
    m0 = total_mass(geom, st.eta)
    dt = dg2d.cfl_dt(geom, b)
    step = jax.jit(lambda s: dg2d.ssprk3_step(
        lambda x: dg2d.external_rhs(geom, b, x), s, dt))
    for _ in range(20):
        st = step(st)
    m1 = total_mass(geom, st.eta)
    area = float(geom.area.sum())
    assert abs(m1 - m0) < 1e-7 * area, (m0, m1)


def test_gravity_wave_period():
    """Standing wave in a closed flat basin: eta = eps*cos(pi x/L).
    Exact period T = 2L/c with c = sqrt(gH). After one period the initial
    pattern must reappear (correlation > 0.97)."""
    lx, ly, depth = 1000.0, 400.0, 10.0
    m, geom, b = make(nx=32, ny=8, lx=lx, ly=ly, jitter=0.15, depth=depth)
    eps = 1e-3  # linear regime
    eta0 = eps * jnp.cos(jnp.pi * geom.node_x / lx)
    st = State2D(eta0, jnp.zeros_like(eta0), jnp.zeros_like(eta0))
    c = np.sqrt(G_ * depth)
    T = 2 * lx / c
    n_steps = 400
    dt = T / n_steps
    assert dt < dg2d.cfl_dt(geom, b, cfl=0.8)
    step = jax.jit(lambda s: dg2d.ssprk3_step(
        lambda x: dg2d.external_rhs(geom, b, x), s, dt))
    for _ in range(n_steps):
        st = step(st)
    a = np.asarray(eta0).ravel()
    bb = np.asarray(st.eta).ravel()
    corr = float(np.dot(a, bb) / (np.linalg.norm(a) * np.linalg.norm(bb)))
    assert corr > 0.97, corr


def test_energy_dissipation():
    """LF fluxes + walls must not create energy in a closed basin."""
    m, geom, b = make(shelf=True)
    key = jax.random.PRNGKey(3)
    eta = 0.05 * jax.random.normal(key, (3, m.nt))
    st = State2D(eta, jnp.zeros_like(eta), jnp.zeros_like(eta))
    e0 = total_energy(geom, b, st)
    dt = dg2d.cfl_dt(geom, b)
    step = jax.jit(lambda s: dg2d.ssprk3_step(
        lambda x: dg2d.external_rhs(geom, b, x), s, dt))
    es = [e0]
    for _ in range(50):
        st = step(st)
        es.append(total_energy(geom, b, st))
    assert es[-1] <= es[0] * (1 + 1e-5), es
    assert np.isfinite(es).all()


def test_run_external_f2d_identity():
    """F2D definition (paper eq. 6) must satisfy
    Q1 = Q0 + dt*(F3D2D + F2D) exactly."""
    m, geom, b = make()
    st0 = zeros_state(m.nt)
    f3x = 1e-4 * jnp.ones((3, m.nt))
    f3y = -2e-4 * jnp.ones((3, m.nt))
    dt = 10 * dg2d.cfl_dt(geom, b)
    res = jax.jit(lambda s: dg2d.run_external(
        geom, b, s, dt, m=10, f3d2d_x=f3x, f3d2d_y=f3y))(st0)
    # Q1 = Q0 + dt*(Minv F3D2D + F2D): F3D2D is raw-assembled, F2D nodal
    np.testing.assert_allclose(
        np.asarray(res.state.qx),
        np.asarray(st0.qx + dt * (geometry.minv_apply(geom, f3x) + res.f2d_x)),
        rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(
        np.asarray(res.state.qy),
        np.asarray(st0.qy + dt * (geometry.minv_apply(geom, f3y) + res.f2d_y)),
        rtol=1e-4, atol=1e-8)
    assert res.q_bar_x.shape == (3, m.nt)
    assert res.fbar_edge.shape == (3, 2, m.nt)


def test_open_boundary_tidal_inflow():
    """Channel with tidal elevation at open ends: flow develops, stays finite,
    and responds in the right direction (high eta at x=0 drives +x flow)."""
    mch = mesh2d.channel_mesh(24, 6, 3000.0, 600.0, jitter=0.1)
    geom = geometry.geom2d_from_mesh(mch)
    b = jnp.full((3, mch.nt), 10.0)
    amp = 0.2
    eta_bc = amp * (1.0 - geom.node_x / 3000.0)  # ~amp at x=0, 0 at x=L
    st = zeros_state(mch.nt)
    dt = dg2d.cfl_dt(geom, b)
    forcing = Forcing2D(eta_open=eta_bc)
    step = jax.jit(lambda s: dg2d.ssprk3_step(
        lambda x: dg2d.external_rhs(geom, b, x, forcing), s, dt))
    for _ in range(100):
        st = step(st)
    qx = np.asarray(st.qx)
    assert np.isfinite(qx).all()
    assert qx.mean() > 1e-4  # net +x transport develops
