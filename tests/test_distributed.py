"""Distributed (shard_map + halo exchange) correctness tests.

The decisive test: the sharded multi-device step must reproduce the
single-device step on the owned cells — for the paper-faithful per-stage
exchange AND the communication-avoiding k-halo variant.  Device-count
spoofing requires a fresh process, so the heavy checks run in subprocesses.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import mesh2d
from repro.distributed import partition

# ---------------------------------------------------------------------------
# partition-building invariants (run in-process, numpy only)
# ---------------------------------------------------------------------------

def test_partition_covers_mesh():
    m = mesh2d.rect_mesh(16, 16, 1.0, 1.0, jitter=0.2, seed=1)  # nt = 512
    spec = partition.build_partition(m, 8, halo_depth=1)
    ids = spec.glob_ids[:, :spec.n_own].ravel()
    assert sorted(ids.tolist()) == list(range(m.nt))


def test_partition_halo_contains_all_neighbours():
    m = mesh2d.rect_mesh(16, 16, 1.0, 1.0, jitter=0.2, seed=1)
    spec = partition.build_partition(m, 8, halo_depth=1)
    for p in range(8):
        own = set(range(p * spec.n_own, (p + 1) * spec.n_own))
        local = set(spec.glob_ids[p].tolist())
        for t in own:
            for n in m.neigh_tri[t]:
                assert int(n) in local, (p, t, n)


def test_partition_exchange_tables_consistent():
    """Sending p's owned slot for triangle t must land in the receiver's halo
    slot for the same global triangle."""
    m = mesh2d.rect_mesh(16, 16, 1.0, 1.0, jitter=0.2, seed=1)
    spec = partition.build_partition(m, 8, halo_depth=2)
    trash = spec.n_loc - 1
    for off, (send, recv) in spec.tables.items():
        for src in range(8):
            dst = (src + off) % 8
            for j in range(send.shape[1]):
                r = recv[dst, j]
                if r == trash:
                    continue
                g_sent = spec.glob_ids[src, send[src, j]]
                g_recv = spec.glob_ids[dst, r]
                assert g_sent == g_recv, (off, src, j)


def test_scatter_gather_roundtrip():
    m = mesh2d.rect_mesh(16, 16, 1.0, 1.0, jitter=0.2, seed=1)
    spec = partition.build_partition(m, 8, halo_depth=1)
    f = np.random.default_rng(0).normal(size=(3, m.nt))
    back = partition.gather_field(spec, partition.scatter_field(spec, f))
    np.testing.assert_array_equal(back, f)


# ---------------------------------------------------------------------------
# full equivalence in a subprocess with 8 spoofed devices
# ---------------------------------------------------------------------------
_EQUIV_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.core import geometry, mesh2d, stepper
from repro.core.extrusion import VGrid
from repro.distributed.ocean import DistributedOcean

period = {period}
mesh = mesh2d.rect_mesh(16, 8, 4000.0, 2000.0, jitter=0.2, seed=4)  # nt=256
geom = geometry.geom2d_from_mesh(mesh)
b = np.full((3, mesh.nt), 20.0, np.float32)
cfg = stepper.OceanConfig(nl=3, dt=24.0, m_2d=12, use_gls=True,
                          eos_kind="linear", halo_exchange_period=period)
vg = VGrid(b=jnp.asarray(b), nl=3)
st = stepper.init_state(geom, vg)
eta0 = (0.05 * jnp.cos(jnp.pi * geom.node_x / 4000.0)
        * jnp.cos(jnp.pi * geom.node_y / 2000.0))
Tf = 10.0 + 2.0 * jnp.exp(-((geom.node_x - 1000.0) ** 2
                            + (geom.node_y - 800.0) ** 2) / 5e5)
T0 = jnp.broadcast_to(jnp.concatenate([Tf, Tf])[None], st.T.shape)
st = stepper.OceanState(ext=stepper.State2D(eta0, st.ext.qx, st.ext.qy),
                        ux=st.ux, uy=st.uy, T=T0, S=st.S,
                        turb_k=st.turb_k, turb_eps=st.turb_eps,
                        nu_t=st.nu_t, kappa_t=st.kappa_t, time=st.time)

# single device reference
step1 = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
ref = st
for _ in range(3):
    ref = step1(ref)

# distributed over 8 devices
dmesh = jax.make_mesh((2, 4), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
do = DistributedOcean(mesh, b, cfg, dmesh, ("data", "model"))
stk = do.scatter_state(st)
stepd = do.make_step()
for _ in range(3):
    stk = stepd(stk)
out = do.gather_state(stk)

for name in ("ux", "uy", "T", "S"):
    a = np.asarray(getattr(ref, name))
    bb = np.asarray(getattr(out, name))
    err = np.abs(a - bb).max()
    scale = np.abs(a).max() + 1e-12
    assert err < 5e-5 * max(scale, 1.0), (name, err, scale)
ea = np.asarray(ref.ext.eta); eb = np.asarray(out.ext.eta)
assert np.abs(ea - eb).max() < 5e-5, np.abs(ea - eb).max()
assert np.abs(np.asarray(ref.T)).max() > 10.0  # blob alive
print("EQUIV_OK period=", period)
'''


def _run_equiv(period):
    res = subprocess.run(
        [sys.executable, "-c", _EQUIV_SCRIPT.format(period=period)],
        capture_output=True, text=True, timeout=1500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stdout + res.stderr
    assert "EQUIV_OK" in res.stdout


@pytest.mark.slow
def test_distributed_equivalence_per_stage():
    """Paper-faithful: halo exchange before every 2D RK stage (1-deep halo)."""
    _run_equiv(0)


@pytest.mark.slow
def test_distributed_equivalence_comm_avoiding():
    """Beyond-paper: 2-substep exchange period with 6-deep halos."""
    _run_equiv(2)
