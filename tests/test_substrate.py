"""Training substrate tests: optimizer, data, checkpoint, fault tolerance,
gradient compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.data.pipeline import ForcingWindow, TokenDataset, interp_forcing
from repro.optim import adamw
from repro.runtime.fault_tolerance import RunnerConfig, TrainRunner


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw.init(params)
    loss_fn = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    l0 = float(loss_fn(params))
    for _ in range(100):
        grads = jax.grad(loss_fn)(params)
        params, state = adamw.update(grads, state, params, cfg)
    assert float(loss_fn(params)) < 1e-2 * l0


def test_adamw_clipping():
    params = {"w": jnp.asarray([1.0])}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    grads = {"w": jnp.asarray([1e6])}
    p1, _ = adamw.update(grads, state, params, cfg)
    assert abs(float(p1["w"][0]) - 1.0) < 1.5  # update bounded by lr

def test_token_dataset_deterministic_resume():
    ds = TokenDataset(vocab=100, seq_len=16, global_batch=4, seed=3)
    b5 = ds.batch_at(5)
    b5b = ds.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b5["tokens"]),
                                  np.asarray(b5b["tokens"]))
    # labels are next-token shifted
    ds2 = TokenDataset(vocab=100, seq_len=16, global_batch=4, seed=3)
    b = ds2.batch_at(0)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)


def test_forcing_window_interpolation():
    calls = []
    def provider(k):
        calls.append(k)
        return {"f": jnp.full((3,), float(k))}
    fw = ForcingWindow(provider, dt_window=3600.0, prefetch=False)
    f0, f1, t0, t1 = fw.at(1800.0)
    v = interp_forcing(f0["f"], f1["f"], t0, t1, jnp.asarray(1800.0))
    np.testing.assert_allclose(np.asarray(v), 0.5, rtol=1e-6)
    # advance two windows
    f0, f1, t0, t1 = fw.at(2.5 * 3600.0)
    v = interp_forcing(f0["f"], f1["f"], t0, t1, jnp.asarray(2.5 * 3600.0))
    np.testing.assert_allclose(np.asarray(v), 2.5, rtol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.asarray(3), "d": (jnp.ones(4), jnp.zeros(2))}}
    ck.save(10, tree, blocking=True)
    ck.save(20, tree, blocking=True)
    ck.save(30, tree, blocking=True)
    assert ck.latest_step() == 30
    # keep_last pruning
    steps = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(steps) == 2
    out = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["d"][0]), np.ones(4))


def test_runner_resume_and_crash_recovery(tmp_path):
    """Runner must checkpoint, survive injected failures by restoring, and
    resume exactly."""
    ds = TokenDataset(vocab=10, seq_len=4, global_batch=2, seed=0)
    fail_at = {7}

    def step_fn(state, batch):
        step = int(state["step"])
        if step in fail_at:
            fail_at.clear()          # fail once
            raise RuntimeError("injected device failure")
        return ({"step": state["step"] + 1,
                 "acc": state["acc"] + float(batch["tokens"].sum())},
                {"loss": 1.0})

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2,
                       max_retries=2)
    runner = TrainRunner(step_fn, ds, cfg)
    state = {"step": jnp.asarray(0), "acc": jnp.asarray(0.0)}
    out = runner.run(state, n_steps=10, resume=False)
    assert int(out["step"]) == 10
    assert runner.stats["retries"] == 1
    # deterministic accumulation despite the crash: recompute reference
    ref = 0.0
    for s in range(10):
        ref += float(ds.batch_at(s)["tokens"].sum())
    assert abs(float(out["acc"]) - ref) < 1e-6


def test_elastic_restore_new_topology(tmp_path):
    """Checkpoints restore onto a different device layout (subprocess with 8
    spoofed devices saves; this process (1 device) restores)."""
    script = f'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import Checkpointer
mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh, P("data", None)))
ck = Checkpointer({str(tmp_path)!r})
ck.save(5, {{"x": x}}, blocking=True)
print("SAVED")
'''
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300,
                         env={"PYTHONPATH": "src", "HOME": "/root",
                              "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo")
    assert "SAVED" in res.stdout, res.stdout + res.stderr
    ck = Checkpointer(str(tmp_path))
    out = ck.restore({"x": jnp.zeros((8, 8))})
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.arange(64.0).reshape(8, 8))


def test_compressed_grad_psum_subprocess():
    """int8 error-feedback DP gradient compression: mean over devices close
    to f32 all-reduce per step; error feedback keeps cumulative drift small."""
    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_grad_psum, init_error_state

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
def f(g, e):
    m, e2 = compressed_grad_psum({"w": g}, {"w": e}, "data", 8)
    return m["w"], e2["w"]
sh = jax.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=(P("data"), P("data")))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 1000)).astype(np.float32))
e = jnp.zeros((8, 1000), jnp.float32)
cum_c, cum_t = 0.0, 0.0
for step in range(20):
    gs = g * (1.0 + 0.1 * step)
    mean_c, e = sh(gs, e)
    true = jnp.broadcast_to(gs.mean(0, keepdims=True), gs.shape)
    err = float(jnp.abs(mean_c - true).max())
    scale = float(jnp.abs(true).max())
    assert err < 0.02 * scale + 1e-6, (step, err, scale)
print("COMPRESS_OK")
'''
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "HOME": "/root",
                              "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo")
    assert "COMPRESS_OK" in res.stdout, res.stdout + res.stderr
