"""GLS turbulence and EOS unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import eos, turbulence


def test_thomas_vs_numpy():
    rng = np.random.default_rng(0)
    nl, nt = 12, 37
    dl = jnp.asarray(rng.normal(size=(nl, nt)) * 0.3)
    du = jnp.asarray(rng.normal(size=(nl, nt)) * 0.3)
    d = jnp.asarray(2.0 + rng.random((nl, nt)))
    b = jnp.asarray(rng.normal(size=(nl, nt)))
    x = turbulence.thomas_solve(dl, d, du, b)
    for t in range(0, nt, 7):
        A = np.zeros((nl, nl))
        for i in range(nl):
            A[i, i] = d[i, t]
            if i > 0:
                A[i, i - 1] = dl[i, t]
            if i < nl - 1:
                A[i, i + 1] = du[i, t]
        xd = np.linalg.solve(A, np.asarray(b[:, t]))
        np.testing.assert_allclose(np.asarray(x[:, t]), xd, rtol=1e-10)


def test_gls_positivity_and_equilibrium():
    """k and eps stay positive; with strong shear nu_t grows, without it
    nu_t decays toward background."""
    nl, nt = 8, 5
    ts = turbulence.init_turbulence(nl, nt, jnp.float64)
    dz = jnp.full((1, nt), 2.0)
    m2 = jnp.full((nl, nt), 1e-4)   # shear
    n2 = jnp.zeros((nl, nt))
    for _ in range(50):
        ts = turbulence.gls_step(ts, m2, n2, dz, dt=30.0)
        assert float(ts.k.min()) > 0
        assert float(ts.eps.min()) > 0
    nu_sheared = float(ts.nu_t.mean())
    ts2 = turbulence.init_turbulence(nl, nt, jnp.float64)
    for _ in range(50):
        ts2 = turbulence.gls_step(ts2, jnp.zeros((nl, nt)), n2, dz, dt=30.0)
    assert nu_sheared > 10 * float(ts2.nu_t.mean())


def test_gls_stable_stratification_suppresses_mixing():
    nl, nt = 8, 3
    dz = jnp.full((1, nt), 2.0)
    m2 = jnp.full((nl, nt), 1e-4)
    def run(n2val):
        ts = turbulence.init_turbulence(nl, nt, jnp.float64)
        for _ in range(50):
            ts = turbulence.gls_step(ts, m2, jnp.full((nl, nt), n2val), dz, 30.0)
        return float(ts.nu_t.mean())
    assert run(1e-3) < run(0.0)


def test_jackett_reference_values():
    """Sanity: fresh cold water ~ 1000; standard seawater ~ 1027-1028 at
    surface; density increases with S, decreases with T, increases with p."""
    r0 = float(eos.rho_jackett(jnp.asarray(0.0), jnp.asarray(5.0), jnp.asarray(0.0)))
    assert abs(r0 - 1000.0) < 0.2
    r35 = float(eos.rho_jackett(jnp.asarray(35.0), jnp.asarray(10.0), jnp.asarray(0.0)))
    assert 1026.0 < r35 < 1028.0
    assert float(eos.rho_jackett(jnp.asarray(36.0), jnp.asarray(10.0), jnp.asarray(0.0))) > r35
    assert float(eos.rho_jackett(jnp.asarray(35.0), jnp.asarray(15.0), jnp.asarray(0.0))) < r35
    assert float(eos.rho_jackett(jnp.asarray(35.0), jnp.asarray(10.0), jnp.asarray(1000.0))) > r35


def test_linear_eos():
    r = eos.rho_prime(jnp.asarray(35.0), jnp.asarray(12.0), None, "linear")
    np.testing.assert_allclose(float(r), -0.2 * 2.0, rtol=1e-12)
