"""Flight-recorder tests: metrics registry + schema, physics diagnostics
(conservation to roundoff, NaN localisation), monitor policy, the
fault-tolerance NaN path, and the bench artifact plumbing."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import dg2d, geometry, mesh2d, stepper
from repro.core.extrusion import VGrid
from repro.obs import diagnostics as obs_diag
from repro.obs import metrics, schema
from repro.runtime.fault_tolerance import RunnerConfig, TrainRunner

F64 = jnp.float64


def build(nx=6, ny=5, lx=2000.0, ly=1500.0, depth=20.0, nl=4):
    m = mesh2d.rect_mesh(nx, ny, lx, ly, jitter=0.2, seed=3)
    geom = geometry.geom2d_from_mesh(m, dtype=F64)
    vg = VGrid(b=jnp.full((3, m.nt), depth, F64), nl=nl)
    return m, geom, vg


def standing_wave_state(geom, vg, lx=2000.0, amp=0.05):
    st = stepper.init_state(geom, vg, dtype=F64)
    eta = (amp * jnp.cos(jnp.pi * geom.node_x / lx)).astype(F64)
    return dataclasses.replace(
        st, ext=dg2d.State2D(eta, st.ext.qx, st.ext.qy))


# ---------------------------------------------------------------------------
# metrics registry + schema
# ---------------------------------------------------------------------------
def test_registry_roundtrip_jsonl(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    reg = metrics.Registry(sink=metrics.JsonlSink(path))
    reg.counter("kernel_dispatch", op="solve_r", backend="ref").inc(3)
    reg.gauge("runner.step_time_ema_s").set(0.125)
    h = reg.histogram("stage_time_us", stage="imex.stage1")
    for v in (10.0, 20.0, 30.0):
        h.observe(v)
    reg.event("monitor.violation", {"rule": "cfl_2d", "value": 1.5}, step=2)
    reg.diagnostics("physics", {"volume": 1.0, "nonfinite": False,
                                "eta_max": float("nan")}, step=2)
    reg.flush(step=3)
    reg.close()

    n_ok, errors = schema.validate_file(path)
    assert errors == [], errors
    assert n_ok == 5  # event + diagnostics + counter + gauge + histogram
    recs = [json.loads(l) for l in open(path)]
    diag = next(r for r in recs if r["kind"] == "diagnostics")
    assert diag["value"]["eta_max"] is None  # NaN sanitised to null
    hist = next(r for r in recs if r["kind"] == "histogram")
    assert hist["value"]["p50"] == 20.0 and hist["value"]["count"] == 3
    snap = reg.snapshot()
    assert snap["counter"][
        "kernel_dispatch{backend=ref,op=solve_r}"] == 3.0


def test_schema_rejects_malformed():
    with pytest.raises(schema.SchemaError):
        schema.validate_record({"ts": 0.0, "kind": "bogus", "name": "x"})
    with pytest.raises(schema.SchemaError):
        schema.validate_record({"ts": 0.0, "kind": "counter", "name": "x",
                                "value": -1})
    with pytest.raises(schema.SchemaError):
        schema.validate_record({"kind": "gauge", "name": "x", "value": 1})
    # strict JSON: bare NaN literals are schema violations, not valid JSON
    n_ok, errors = schema.validate_lines(
        ['{"ts": 1.0, "kind": "gauge", "name": "g", "value": NaN}'])
    assert n_ok == 0 and len(errors) == 1


def test_dispatch_counter_counts_traces():
    metrics.reset()
    from repro.kernels import ops
    a = jnp.ones((4, 128))
    ops.tridiag(a, 4.0 * a, a, a)
    snap = metrics.default().snapshot()["counter"]
    keys = [k for k in snap if k.startswith("kernel_dispatch")]
    assert len(keys) == 1 and "op=tridiag" in keys[0]
    assert snap[keys[0]] >= 1.0
    metrics.reset()


# ---------------------------------------------------------------------------
# benchmarks/common.time_fn
# ---------------------------------------------------------------------------
def test_time_fn_blocks_pytrees_and_reports_percentiles():
    from benchmarks.common import Timing, time_fn

    def fn(x):
        return {"a": x * 2, "b": [x + 1, None, "label"], "t": (x,)}

    t = time_fn(fn, jnp.arange(8.0), warmup=1, iters=5)
    assert isinstance(t, float) and isinstance(t, Timing)
    assert t.min <= t.p50 <= t.p90 and t.n == 5
    assert t * 1e6 > 0.0  # float arithmetic still works
    stats = t.stats()
    assert set(stats) == {"p50", "p90", "min", "mean", "n"}


# ---------------------------------------------------------------------------
# physics diagnostics
# ---------------------------------------------------------------------------
def test_conservation_standing_wave_20_steps():
    """Volume and tracer mass conserved to f64 roundoff over 20 steps."""
    _, geom, vg = build()
    cfg = stepper.OceanConfig(dt=5.0, nl=4, m_2d=6)
    st = standing_wave_state(geom, vg)
    fn = jax.jit(lambda s: obs_diag.step_with_diagnostics(geom, vg, cfg, s))
    st, diag = fn(st)
    d0 = obs_diag.to_dict(diag)
    for _ in range(19):
        st, diag = fn(st)
    d = obs_diag.to_dict(diag)
    assert abs(d["volume"] - d0["volume"]) / d0["volume"] < 1e-12
    assert abs(d["mass_T"] - d0["mass_T"]) / d0["mass_T"] < 1e-12
    assert abs(d["mass_S"] - d0["mass_S"]) / d0["mass_S"] < 1e-12
    assert not d["nonfinite"] and d["bad_cell"] == -1
    assert 0.0 < d["cfl_2d"] < 1.0
    assert 0.0 < d["eta_max"] <= 0.06  # wave oscillates within initial amp


def test_nan_localizer_pinpoints_injected_cell():
    _, geom, vg = build()
    cfg = stepper.OceanConfig(dt=5.0, nl=4, m_2d=6)
    st = standing_wave_state(geom, vg)
    bad_cell = 7
    st = dataclasses.replace(
        st, T=st.T.at[2, 4, bad_cell].set(jnp.nan))
    diag = jax.jit(lambda s: obs_diag.compute(geom, vg, cfg, s))(st)
    d = obs_diag.to_dict(diag)
    assert d["nonfinite"]
    assert d["bad_field_name"] == "T"
    assert d["bad_cell"] == bad_cell
    # priority order: a bad eta in a later cell wins over the bad T
    st2 = dataclasses.replace(
        st, ext=dg2d.State2D(st.ext.eta.at[0, 11].set(jnp.inf),
                             st.ext.qx, st.ext.qy))
    d2 = obs_diag.to_dict(obs_diag.compute(geom, vg, cfg, st2))
    assert d2["bad_field_name"] == "eta" and d2["bad_cell"] == 11


def test_monitor_policy_warn_and_halt(tmp_path):
    _, geom, vg = build()
    cfg = stepper.OceanConfig(dt=5.0, nl=4, m_2d=6)
    st = standing_wave_state(geom, vg)
    diag = obs_diag.compute(geom, vg, cfg, st)

    ok = obs_diag.MonitorPolicy(cfl_max=1.0, on_violation="halt")
    assert ok.check(diag) == []

    path = str(tmp_path / "m.jsonl")
    reg = metrics.Registry(sink=metrics.JsonlSink(path))
    warn = obs_diag.MonitorPolicy(cfl_max=1e-6, eta_max=1e-3,
                                  on_violation="warn")
    with pytest.warns(RuntimeWarning, match="cfl_2d"):
        v = warn.check(diag, step=0, registry=reg)
    assert {x["rule"] for x in v} == {"cfl_2d", "eta_max"}
    reg.close()
    n_ok, errors = schema.validate_file(path)
    assert errors == [] and n_ok == 3  # 1 diagnostics + 2 violation events

    halt = obs_diag.MonitorPolicy(cfl_max=1e-6, on_violation="halt")
    with pytest.raises(obs_diag.MonitorHalt) as ei:
        halt.check(diag)
    assert ei.value.violations[0]["rule"] == "cfl_2d"

    # tracer bounds + drift vs first-check reference
    drift = obs_diag.MonitorPolicy(
        cfl_max=None, tracer_bounds={"T": (9.9, 10.1)},
        volume_drift_max=1e-12, on_violation="silent")
    assert drift.check(diag) == []          # captures reference
    bigger = dataclasses.replace(diag, volume=diag.volume * 1.01,
                                 T_max=jnp.asarray(11.0))
    v = drift.check(bigger)
    assert {x["rule"] for x in v} == {"T_max", "volume_drift"}


# ---------------------------------------------------------------------------
# fault tolerance: NaN diagnostics -> restore-and-retry
# ---------------------------------------------------------------------------
class _CountingDataset:
    def batch_at(self, step):
        return {"x": jnp.asarray(float(step))}


def test_runner_retries_on_nonfinite_diagnostics(tmp_path):
    failed = {"done": False}

    def step_fn(state, batch):
        s = int(state["step"])
        new = {"step": state["step"] + 1,
               "acc": state["acc"] + batch["x"]}
        diag = {"nonfinite": False, "bad_field_name": None, "bad_cell": -1}
        if s == 5 and not failed["done"]:
            failed["done"] = True   # fail exactly once, first time at step 5
            diag = {"nonfinite": True, "bad_field_name": "T", "bad_cell": 7}
        return new, {"loss": 1.0, "diagnostics": diag}

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2,
                       max_retries=2, emit_metrics=False)
    runner = TrainRunner(step_fn, _CountingDataset(), cfg)
    state = {"step": jnp.asarray(0), "acc": jnp.asarray(0.0)}
    out = runner.run(state, n_steps=8, resume=False)
    assert int(out["step"]) == 8
    assert runner.stats["retries"] == 1
    # restored to the step-4 checkpoint and re-ran deterministically
    assert float(out["acc"]) == sum(range(8))


def test_runner_diag_failure_exhausts_retries(tmp_path):
    def step_fn(state, batch):
        return state, {"loss": 1.0,
                       "diagnostics": {"nonfinite": True,
                                       "bad_field_name": "eta",
                                       "bad_cell": 0}}

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=100,
                       max_retries=1, emit_metrics=False)
    runner = TrainRunner(step_fn, _CountingDataset(), cfg)
    with pytest.raises(FloatingPointError, match="field=eta"):
        runner.run({"step": jnp.asarray(0)}, n_steps=3, resume=False)


def test_runner_accepts_diagnostics_pytree(tmp_path):
    """The duck-typed check also reads the Diagnostics dataclass itself."""
    _, geom, vg = build(nx=4, ny=3)
    cfg3 = stepper.OceanConfig(dt=5.0, nl=4, m_2d=6)
    st = standing_wave_state(geom, vg)
    bad = dataclasses.replace(st, T=st.T.at[0, 0, 3].set(jnp.nan))
    diag = obs_diag.compute(geom, vg, cfg3, bad)

    def step_fn(state, batch):
        return state, {"loss": 1.0, "diagnostics": diag}

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), max_retries=0,
                       emit_metrics=False)
    runner = TrainRunner(step_fn, _CountingDataset(), cfg)
    with pytest.raises(FloatingPointError, match="field=T"):
        runner.run({"s": jnp.asarray(0)}, n_steps=1, resume=False)


# ---------------------------------------------------------------------------
# obs_report: bench diff
# ---------------------------------------------------------------------------
def test_obs_report_diff_flags_regression(tmp_path, capsys):
    from benchmarks import obs_report

    old = [dict(name="fused", nl=4, nt=96, us_per_call=100.0),
           dict(name="ref", nl=4, nt=96, us_per_call=200.0),
           dict(kind="breakdown", path="fused", component="continuity",
                nl=16, nt=864, us_per_call=50.0)]
    new = [dict(name="fused", nl=4, nt=96, us_per_call=150.0),   # 1.5x slower
           dict(name="ref", nl=4, nt=96, us_per_call=190.0),
           dict(kind="breakdown", path="fused", component="continuity",
                nl=16, nt=864, us_per_call=49.0)]
    po, pn = tmp_path / "old.json", tmp_path / "new.json"
    po.write_text(json.dumps(old))
    pn.write_text(json.dumps(new))

    rows = obs_report.diff_records(old, new)
    assert len(rows) == 3
    fused = next(r for r in rows if r["key"].startswith("fused"))
    assert fused["ratio"] == pytest.approx(1.5)

    assert obs_report.diff(str(po), str(pn), threshold=0.10, fail=True) == 1
    assert obs_report.diff(str(po), str(pn), threshold=0.60, fail=True) == 0
    capsys.readouterr()
