import jax
import pytest

# Deterministic dtype policy for the whole suite: several test modules need
# f64 (solver exactness); module import order at collection must not change
# behaviour, so x64 is enabled globally and f32-targeted tests pin dtypes.
jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test (subprocess)")


# ---------------------------------------------------------------------------
# hypothesis fallback: the tier-1 suite must collect and pass on a bare
# jax+pytest environment.  When hypothesis is unavailable, install a tiny
# deterministic shim that expands @given(sampled_from/booleans) into a
# pytest.mark.parametrize over the full Cartesian product — every example the
# real hypothesis would draw from these finite strategies, minus shrinking.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import itertools
    import sys
    import types

    class _Strategy:
        def __init__(self, values):
            self.values = list(values)

    def _sampled_from(values):
        return _Strategy(values)

    def _booleans():
        return _Strategy([False, True])

    def _integers(min_value=0, max_value=8):
        return _Strategy(range(min_value, max_value + 1))

    def _given(**strategies):
        names = sorted(strategies)
        combos = list(itertools.product(
            *(strategies[n].values for n in names)))

        def deco(fn):
            if len(names) == 1:
                values = [c[0] for c in combos]
            else:
                values = combos
            return pytest.mark.parametrize(",".join(names), values)(fn)
        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.integers = _integers
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_repro_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
