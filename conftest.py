import jax
import pytest

# Deterministic dtype policy for the whole suite: several test modules need
# f64 (solver exactness); module import order at collection must not change
# behaviour, so x64 is enabled globally and f32-targeted tests pin dtypes.
jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test (subprocess)")
