"""Fused horizontal-RHS pipeline: per-stage interpolation caches (ISSUE 4).

Each IMEX stage evaluates the horizontal DG terms several times — momentum
flux prediction, momentum update, tracers, two lateral flux speeds, the
continuity RHS and the pressure gradient — and in the seed every call
independently re-ran the lateral int/ext neighbour gathers, `zinterp`, and
the volume-quad interpolation on fields that are identical across calls
(`jz`, `{Jz/H}`, eta/H edge states, the transport `qxq/qyq`).  XLA does not
deduplicate those gathers across separately-assembled calls, so the hot
path was dominated by repeated gather + interpolation traffic (paper §2;
Klöckner et al.; Modave et al.: the surface kernels are bandwidth-bound on
redundant gathers).

Two cache levels, both plain pytrees so they flow through jit:

  * ``EdgeCache``      — built ONCE per stage from the evaluation-mesh
                         vertical geometry: every field-independent edge /
                         volume interpolation (jz gathers, {Jz/H}, eta/H
                         edge states, sigma3 penalty, edge quad weights).
  * ``TransportCache`` — built once per transport (q for the prediction,
                         q-bar for the corrected update): vol-quad transport
                         `qxq/qyq` shared by `horizontal_advdiff` and
                         `continuity_rhs`, plus the LateralFlux speeds.

`dg3d.horizontal_advdiff`, `lateral_flux_speed`, `continuity_rhs` and
`pressure_gradient_rhs` consume these via their ``cache``/``tcache``
arguments; `advdiff_momentum_tracers` additionally batches momentum and
tracers into a single k-stacked advdiff call (their flux speeds coincide).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import dg3d
from . import geometry as G
from .extrusion import VertGeom
from ..kernels import dispatch as _dispatch


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeCache:
    """Field-independent per-stage interpolations (one build per stage)."""
    jz_q: jax.Array      # (3qh, nt)   vol-quad J_z
    jz_int: jax.Array    # (3, 2, nt)  interior J_z at lateral qps
    jz_ext: jax.Array    # (3, 2, nt)  exterior (gathered) J_z
    jz_mean: jax.Array   # (3, 2, nt)  {J_z}
    alpha: jax.Array     # (3, 2, nt)  {Jz/H} lateral coefficient
    H_int: jax.Array     # (3, 2, nt)  column height edge states
    H_ext: jax.Array
    eta_int: jax.Array   # (3, 2, nt)  free-surface edge states
    eta_ext: jax.Array
    sigma3: jax.Array    # (3, nt)     interior-penalty coefficient


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TransportCache:
    """Per-transport interpolations (one build per transport per stage)."""
    qxq: jax.Array       # (nl, 2qz, 3qh, nt) vol-quad transport
    qyq: jax.Array
    flux: dg3d.LateralFlux


def stage_cache(geom: G.Geom2D, vge: VertGeom,
                h_min: float = 0.05) -> EdgeCache:
    """Build the per-stage EdgeCache from the evaluation-mesh geometry.

    This is the ONLY place the stage gathers exterior states of jz, Jz/H,
    H, and eta — the structural one-per-stage guarantee asserted by
    tests/test_horizontal.py's call-count test.  (Edge quadrature weights
    need no cache slot: geometry.edge_scatter bakes the scatter tensor in
    as trace-time constants.)"""
    jz_int = G.edge_interp(vge.jz)
    jz_ext = G.edge_interp_ext(geom, vge.jz)
    a = vge.jz / jnp.maximum(vge.H, h_min)
    ai = G.edge_interp(a)
    ae = G.edge_interp_ext(geom, a)
    return EdgeCache(
        jz_q=G.vol_interp(vge.jz),
        jz_int=jz_int, jz_ext=jz_ext, jz_mean=0.5 * (jz_int + jz_ext),
        alpha=0.5 * (ai + ae),
        H_int=G.edge_interp(vge.H),
        H_ext=G.edge_interp_ext(geom, vge.H),
        eta_int=G.edge_interp(vge.eta),
        eta_ext=G.edge_interp_ext(geom, vge.eta),
        sigma3=dg3d.sigma3_lateral(geom))


def transport_cache(geom: G.Geom2D, vge: VertGeom, vg, cache: EdgeCache,
                    qx: jax.Array, qy: jax.Array,
                    fbar_edge=None, qbar2d=None,
                    h_min: float = 0.05) -> TransportCache:
    """Flux speeds + vol-quad interp of one transport, sharing EdgeCache.

    The free surface and bathymetry are taken from vge / vg — the cached
    eta/H edge states in `cache` were built from the same vge, so there is
    no way to pass an inconsistent surface."""
    flux = dg3d.lateral_flux_speed(
        geom, vge, vg, qx, qy, vge.eta, vg.b, fbar_edge=fbar_edge,
        qbar2d=qbar2d, h_min=h_min, cache=cache)
    return TransportCache(qxq=G.vol_interp(dg3d.zinterp(qx)),
                          qyq=G.vol_interp(dg3d.zinterp(qy)), flux=flux)


def concat_states(a: dg3d.FieldStates, b: dg3d.FieldStates) -> dg3d.FieldStates:
    """Stack two FieldStates along the field axis (batched advdiff input)."""
    cat = lambda x, y: jnp.concatenate([x, y], axis=0)
    fx = cat(a.fx, b.fx) if (a.fx is not None and b.fx is not None) else None
    return dg3d.FieldStates(
        fq=cat(a.fq, b.fq), fqq=cat(a.fqq, b.fqq), fi=cat(a.fi, b.fi),
        fe=cat(a.fe, b.fe), fx=fx, gradf=cat(a.gradf, b.gradf),
        gno=cat(a.gno, b.gno), gradf_e=cat(a.gradf_e, b.gradf_e))


def advdiff_momentum_tracers(geom: G.Geom2D, vge: VertGeom, nl: int,
                             u_pair: jax.Array, tr_pair: jax.Array,
                             qx: jax.Array, qy: jax.Array,
                             flux: dg3d.LateralFlux,
                             nu_m: jax.Array, nu_tr: jax.Array,
                             fs_u=None, fs_tr=None, diff_u=None,
                             open_tr=None, cache=None, tcache=None,
                             backend="ref"):
    """Momentum + tracer horizontal RHS sharing one LateralFlux (q-bar).

    fs_u / fs_tr are the per-stage FieldStates (fs_u is shared with the
    momentum *prediction* call, which interpolates the same velocity);
    diff_u is the momentum diffusion term if the stage already built it —
    it is flux-independent, so prediction and update share ONE evaluation.
    open_tr is the optional (2, nl, 6, nt) open-boundary tracer forcing,
    used only when fs_tr is not prebuilt.

    On kernel backends the advection runs as ONE k=4-stacked call — the k
    fields fold into extra cell columns (lanes) of the lateral-flux
    kernel.  On the ref backend two advection calls are kept: the stacking
    requires concatenating the FieldStates, which materialises arrays XLA
    would otherwise fuse into their consumers (measured slower on CPU).

    Returns (f3h_momentum (2, ...), f3h_tracers (2, ...))."""
    nodal = cache is not None
    if fs_u is None:
        fs_u = dg3d.field_states(geom, u_pair, bc_reflect=True, nodal=nodal)
    if fs_tr is None:
        fs_tr = dg3d.field_states(geom, tr_pair, open_values=open_tr,
                                  nodal=nodal)
    if _dispatch.resolve(backend) is _dispatch.Backend.REF:
        adv_m = dg3d.horizontal_advection(geom, vge, nl, u_pair, qx, qy,
                                          flux, tcache=tcache, fcache=fs_u,
                                          backend=backend)
        adv_t = dg3d.horizontal_advection(geom, vge, nl, tr_pair, qx, qy,
                                          flux, tcache=tcache, fcache=fs_tr,
                                          backend=backend)
    else:
        f = jnp.concatenate([u_pair, tr_pair], axis=0)
        adv = dg3d.horizontal_advection(
            geom, vge, nl, f, qx, qy, flux, tcache=tcache,
            fcache=concat_states(fs_u, fs_tr), backend=backend)
        adv_m, adv_t = adv[:2], adv[2:]
    if diff_u is None:
        diff_u = dg3d.horizontal_diffusion(geom, vge, nl, u_pair, nu_m,
                                           cache=cache, fcache=fs_u)
    diff_t = dg3d.horizontal_diffusion(geom, vge, nl, tr_pair, nu_tr,
                                       cache=cache, fcache=fs_tr)
    return adv_m + diff_u, adv_t + diff_t
