"""SoA <-> cell layout transforms (paper §2.1).

The paper groups 128 prism columns into a *cell* and stores a scalar field as
a (rows = 6*n_layers, cols = 128) matrix per cell so that 128 CUDA threads
solving 128 independent column systems get perfectly coalesced access.

On TPU this layout is even more natural: an array shaped
    (n_cells, rows, 128)
puts the 128 columns of a cell in the **lane** dimension — every row load is a
native (8,128)-tile access and a sequential sweep over rows (layers) performs
128 independent column solves per vector op.  `CELL` (=128) matches both the
paper's cell width and the TPU lane count; this is the central hardware
adaptation of the paper's idea (DESIGN.md §2).

Row ordering within a cell matches the paper's Figure 5:
cell -> layer -> node -> column, i.e. row = layer*6 + node.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CELL = 128


def num_cells(nt: int, cell: int = CELL) -> int:
    return (nt + cell - 1) // cell


def pad_nt(x: jax.Array, cell: int = CELL) -> jax.Array:
    """Pad the minor (triangle/column) axis to a multiple of `cell`."""
    nt = x.shape[-1]
    pad = (-nt) % cell
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def soa_to_cell(x: jax.Array, cell: int = CELL) -> jax.Array:
    """(..., nl, nodes, nt) -> (..., n_cells, nl*nodes, cell).

    Works for 3D fields (nl, 6, nt) and 2D per-column data (1, 3, nt) alike.
    Pads nt up to a multiple of `cell`.
    """
    x = pad_nt(x, cell)
    *lead, nl, nn, nt = x.shape
    nc = nt // cell
    x = x.reshape(*lead, nl, nn, nc, cell)
    # -> (..., nc, nl, nn, cell): row = layer*nn + node  (paper Fig. 5)
    x = jnp.moveaxis(x, -2, -4)
    return x.reshape(*lead, nc, nl * nn, cell)


def cell_to_soa(x: jax.Array, nl: int, nn: int, nt: int,
                cell: int = CELL) -> jax.Array:
    """Inverse of soa_to_cell; slices padding back off to `nt`."""
    *lead, nc, rows, c = x.shape
    assert rows == nl * nn and c == cell
    x = x.reshape(*lead, nc, nl, nn, cell)
    x = jnp.moveaxis(x, -4, -2)            # (..., nl, nn, nc, cell)
    x = x.reshape(*lead, nl, nn, nc * cell)
    return x[..., :nt]


def blocks_to_cell(blk: jax.Array, cell: int = CELL) -> jax.Array:
    """Operator blocks (..., nl, 6, 6, nt) -> (..., nc, nl, 6, 6, cell).

    The per-cell operand layout of the paper's column solver (§2.4): each
    cell holds the 6x6 blocks of its 128 columns in the lane dimension.  The
    Pallas kernel consumes the flat lane view (nl, 6, 6, nc*cell) — identical
    bytes, cells walked by the grid — so this explicit form is for step-
    boundary storage and tests."""
    blk = pad_nt(blk, cell)
    *lead, nl, a, b, nt = blk.shape
    nc = nt // cell
    blk = blk.reshape(*lead, nl, a, b, nc, cell)
    return jnp.moveaxis(blk, -2, -5)


def cell_to_blocks(blk: jax.Array, nt: int, cell: int = CELL) -> jax.Array:
    """Inverse of blocks_to_cell; slices padding back off to nt."""
    *lead, nc, nl, a, b, c = blk.shape
    assert c == cell
    blk = jnp.moveaxis(blk, -5, -2).reshape(*lead, nl, a, b, nc * cell)
    return blk[..., :nt]


def soa2d_to_cell(x: jax.Array, cell: int = CELL) -> jax.Array:
    """2D nodal field (..., 3, nt) -> (..., nc, 3, cell)."""
    x = pad_nt(x, cell)
    *lead, nn, nt = x.shape
    nc = nt // cell
    x = x.reshape(*lead, nn, nc, cell)
    return jnp.moveaxis(x, -2, -3)


def cell2d_to_soa(x: jax.Array, nt: int, cell: int = CELL) -> jax.Array:
    *lead, nc, nn, c = x.shape
    x = jnp.moveaxis(x, -3, -2).reshape(*lead, nn, nc * c)
    return x[..., :nt]
