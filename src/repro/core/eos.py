"""Equation of state rho(S, T, p) following Jackett et al. (2006).

The paper computes density from the full Jackett rational-function EOS.  We
implement the 25-term rational polynomial of Jackett et al. (2006) (the same
one used by SLIM / Thetis); a cheap linear EOS is provided for tests.

rho' = rho - rho0 is the density anomaly used by the internal pressure
gradient r (paper eq. 8).
"""
from __future__ import annotations

import jax.numpy as jnp

RHO0 = 1025.0

# Jackett et al. (2006) coefficients (Table A1; rho in kg/m^3, T in deg C,
# S in psu, p in dbar).
_N0 = 9.9984085444849347e2
_N1 = 7.3471625860981584e0
_N2 = -5.3211231792841769e-2
_N3 = 3.6492439109814549e-4
_N4 = 2.5880571023991390e0
_N5 = -6.7168282786692355e-3
_N6 = 1.9203202055760151e-3
_N7 = 1.1798263740430364e-2
_N8 = 9.8920219266399117e-8
_N9 = 4.6996642771754730e-6
_N10 = -2.5862187075154352e-8
_N11 = -3.2921414007960662e-12

_D0 = 1.0
_D1 = 7.2815210113327091e-3
_D2 = -4.4787265461983921e-5
_D3 = 3.3851002965802430e-7
_D4 = 1.3651202389758572e-10
_D5 = 1.7632126669040377e-3
_D6 = -8.8066583251206474e-6
_D7 = -1.8832689434804897e-10
_D8 = 5.7463776745432097e-6
_D9 = 1.4716275472242334e-9
_D10 = 6.7103246285651894e-6
_D11 = -2.4461698007024582e-17
_D12 = -9.1534417604289062e-18


def rho_jackett(S: jnp.ndarray, T: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """In-situ density (kg/m^3). p in dbar (~ depth in m)."""
    T2 = T * T
    sqrtS = jnp.sqrt(jnp.maximum(S, 0.0))
    num = (_N0 + T * (_N1 + T * (_N2 + _N3 * T))
           + S * (_N4 + _N5 * T + _N6 * S)
           + p * (_N7 + _N8 * T2 + _N9 * S + p * (_N10 + _N11 * T2)))
    den = (_D0 + T * (_D1 + T * (_D2 + T * (_D3 + _D4 * T)))
           + S * (_D5 + T * (_D6 + _D7 * T2) + sqrtS * (_D8 + _D9 * T2))
           + p * (_D10 + p * T * (_D11 * T2 + _D12 * p)))
    return num / den


def rho_linear(S, T, p=None, *, alpha=0.2, beta=0.78, T0=10.0, S0=35.0):
    """Linear EOS: rho = rho0 - alpha (T-T0) + beta (S-S0)."""
    return RHO0 - alpha * (T - T0) + beta * (S - S0)


def rho_prime(S, T, p, kind: str = "jackett"):
    """Density anomaly rho' = rho - rho0."""
    if kind == "jackett":
        return rho_jackett(S, T, p) - RHO0
    elif kind == "linear":
        return rho_linear(S, T, p) - RHO0
    else:
        raise ValueError(kind)
