"""GLS two-equation turbulence closure (Umlauf & Burchard 2003), k-epsilon
flavour, discretised per the paper (§2.4): one degree of freedom per prism
(P0 in the vertical), implicit vertical diffusion -> tridiagonal systems per
column solved by the Thomas algorithm (the JAX reference for the Pallas
`tridiag` kernel; columns ride in the lane axis).

Simplifications vs the full GLS family (documented in DESIGN.md):
  * k-epsilon parameter set (p=3, m=1.5, n=-1) only,
  * quasi-equilibrium stability functions reduced to constant c_mu with the
    Galperin stable-stratification length-scale limiter,
  * Patankar-type semi-implicit sources (linearised decay), which keeps k,
    eps positive without clipping artefacts.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

G_GRAV = 9.81


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GLSParams:
    c_mu0: float = 0.5477          # (c_mu^0); nu_t = c_mu0^4 k^2/eps... see note
    c1: float = 1.44
    c2: float = 1.92
    c3_plus: float = 1.0           # unstable stratification
    c3_minus: float = -0.52        # stable stratification
    sigma_k: float = 1.0
    sigma_e: float = 1.3
    k_min: float = 1e-6
    eps_min: float = 1e-10
    nu_min: float = 1e-6
    nu_max: float = 1.0
    galperin: float = 0.56


class TurbState(NamedTuple):
    k: jax.Array      # (nl, nt) TKE per prism
    eps: jax.Array    # (nl, nt) dissipation per prism
    nu_t: jax.Array   # (nl, nt) eddy viscosity
    kappa_t: jax.Array  # (nl, nt) eddy diffusivity


def init_turbulence(nl: int, nt: int, dtype=None) -> TurbState:
    if dtype is None:
        dtype = jnp.zeros(()).dtype
    k = jnp.full((nl, nt), 1e-4, dtype)
    eps = jnp.full((nl, nt), 1e-8, dtype)
    nu = jnp.full((nl, nt), 1e-4, dtype)
    return TurbState(k=k, eps=eps, nu_t=nu, kappa_t=nu)


def thomas_solve(dl: jax.Array, d: jax.Array, du: jax.Array,
                 b: jax.Array) -> jax.Array:
    """Tridiagonal solve, layer axis first: all (nl, nt).

    dl[0] and du[nl-1] are ignored.  This is the pure-JAX oracle for the
    Pallas `tridiag` kernel (columns in lanes, sequential sweep over layers).
    """
    def fwd(carry, x):
        cp, dp = carry
        a, bb, c, r = x
        denom = bb - a * cp
        cpn = c / denom
        dpn = (r - a * dp) / denom
        return (cpn, dpn), (cpn, dpn)

    nl, nt = d.shape
    z = jnp.zeros((nt,), d.dtype)
    _, (cps, dps) = jax.lax.scan(fwd, (z, z), (dl, d, du, b))

    def bwd(xn, x):
        cp, dp = x
        xi = dp - cp * xn
        return xi, xi

    _, xs = jax.lax.scan(bwd, z, (cps, dps), reverse=True)
    return xs


def shear_and_buoyancy(ux: jax.Array, uy: jax.Array, rho_p: jax.Array,
                       dz: jax.Array):
    """M2 (shear^2) and N2 (buoyancy frequency^2) at element centres.

    ux, uy, rho_p: (nl, 6, nt) DG fields; dz: (nl, nt) or (1, nt) thickness.
    Uses the element-mean top/bottom face values.
    """
    def ddz(f):
        ft = f[:, 0:3, :].mean(axis=1)
        fb = f[:, 3:6, :].mean(axis=1)
        return (ft - fb) / dz
    m2 = ddz(ux) ** 2 + ddz(uy) ** 2
    n2 = -(G_GRAV / 1025.0) * ddz(-rho_p)  # z up: N2 = -g/rho0 drho/dz
    return m2, n2


def gls_step(ts: TurbState, m2: jax.Array, n2: jax.Array, dz: jax.Array,
             dt: float, params: GLSParams = GLSParams(),
             surf_k: float = 0.0) -> TurbState:
    """Advance k-eps one step: semi-implicit sources + implicit vertical
    diffusion (tridiagonal per column)."""
    p = params
    nl, nt = ts.k.shape
    k0 = jnp.maximum(ts.k, p.k_min)
    e0 = jnp.maximum(ts.eps, p.eps_min)

    prod = ts.nu_t * m2
    buoy = -ts.kappa_t * n2
    c3 = jnp.where(n2 > 0, p.c3_minus, p.c3_plus)

    # --- semi-implicit source update (Patankar) ----------------------------
    # k: dk/dt = P + B - eps, decay implicit: k1 = (k0 + dt(P + max(B,0)))
    #            / (1 + dt (eps + max(-B,0))/k0)
    k_src = (k0 + dt * (prod + jnp.maximum(buoy, 0.0))) / (
        1.0 + dt * (e0 + jnp.maximum(-buoy, 0.0)) / k0)
    # eps: d(eps)/dt = (eps/k)(c1 P + c3 B - c2 eps); positive sources explicit,
    # decay + stable-buoyancy sink implicit (divided out)
    e_src = (e0 + dt * (e0 / k0) * (p.c1 * prod + jnp.maximum(c3 * buoy, 0.0))) / (
        1.0 + dt * p.c2 * e0 / k0 + dt * jnp.maximum(-c3 * buoy, 0.0) / k0)

    # --- implicit vertical diffusion (tridiagonal per column) ---------------
    def diffuse(f, sigma):
        nu_i = 0.5 * (ts.nu_t[:-1] + ts.nu_t[1:]) / sigma   # interfaces
        dzc = jnp.broadcast_to(dz, f.shape)
        dzi = 0.5 * (dzc[:-1] + dzc[1:])
        w = nu_i / dzi                                       # (nl-1, nt)
        lo = jnp.concatenate([jnp.zeros((1, nt), f.dtype), -dt * w]) / dzc
        up = jnp.concatenate([-dt * w, jnp.zeros((1, nt), f.dtype)]) / dzc
        dg = 1.0 - lo - up
        return thomas_solve(lo, dg, up, f)

    k1 = diffuse(k_src, p.sigma_k)
    e1 = diffuse(e_src, p.sigma_e)
    k1 = jnp.maximum(k1, p.k_min)
    e1 = jnp.maximum(e1, p.eps_min)

    # Galperin limiter under stable stratification: l <= sqrt(0.56 k / N2)
    # with eps = (c_mu0)^3 k^{3/2} / l  -> eps >= (c_mu0)^3 k sqrt(N2/0.56)
    e_lim = (p.c_mu0 ** 3) * k1 * jnp.sqrt(jnp.maximum(n2, 0.0) / p.galperin)
    e1 = jnp.maximum(e1, e_lim)

    cm = p.c_mu0 ** 4  # ~0.09 for c_mu0 = 0.5477 (standard k-eps c_mu)
    nu_t = jnp.clip(cm * k1 ** 2 / e1, p.nu_min, p.nu_max)
    kap_t = jnp.clip(cm / 1.3 * k1 ** 2 / e1, p.nu_min, p.nu_max)
    return TurbState(k=k1, eps=e1, nu_t=nu_t, kappa_t=kap_t)


def to_nodes(f_p0: jax.Array) -> jax.Array:
    """Broadcast P0-per-prism coefficients (nl, nt) to DG nodes (nl, 6, nt)."""
    return jnp.broadcast_to(f_p0[:, None, :], (f_p0.shape[0], 6, f_p0.shape[1]))
