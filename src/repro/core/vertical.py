"""Vertical (column) solvers — the computational heart of the paper.

1. Matrix-free solvers (paper §2.3, Algorithm 1): the systems for the
   hydrostatic pressure gradient r (D_vu r = F) and the vertical velocity w
   (D_vd w = F) have an a-priori-known bidiagonal-of-M_h structure.  After
   applying M_h^{-1} per face they reduce to prefix sums over layers:

     r_b^l = r_surf - sum_{k<=l}(g_t^k + g_b^k),   r_t^l = r_b^l + 2 g_b^l
     w_t^l = w_floor + sum_{k>=l}(g_t^k + g_b^k),  w_b^l = w_t^l - 2 g_t^l

   (derived from the D_vu/D_vd matrices in §2.3; verified against dense
   assembly in tests).  In JAX these are cumsums over the layer axis — the
   TPU analogue of the single-pass CUDA sweep.

2. Fully-assembled column operator (paper §2.4): implicit vertical
   advection + viscosity couples each prism's 6 nodes to the prisms above
   and below -> block-tridiagonal with 6x6 blocks.  We assemble
   (L, D, U) blocks and solve with a block-Thomas elimination scanned over
   layers, batched over all columns (lanes).  The same blocks give the
   explicit matvec F_3D^v(u) for fully-explicit sub-steps.

All 'weighted mass' face integrals use the shared 3-point quadrature of
`geometry` so that discrete consistency holds across every operator.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import geometry as G

# vertical P1 mass on [-1,1]: int phi_a phi_b dzeta
MZ = jnp.array([[2.0 / 3.0, 1.0 / 3.0], [1.0 / 3.0, 2.0 / 3.0]])
# d/dzeta of (top, bottom) vertical basis
SZ = jnp.array([0.5, -0.5])
# vertical basis at the 2 Gauss points (qz, [top,bot])
PHI_Z = jnp.asarray(G.PHI_ZQ)


def _minv_faces(geom: G.Geom2D, F: jax.Array) -> jax.Array:
    """Apply M_h^{-1} to the two 3-node faces of (..., nl, 6, nt)."""
    gt = G.minv_apply(geom, F[..., 0:3, :])
    gb = G.minv_apply(geom, F[..., 3:6, :])
    return jnp.concatenate([gt, gb], axis=-2)


def solve_r(geom: G.Geom2D, F: jax.Array, r_surf: jax.Array) -> jax.Array:
    """Matrix-free top-down solve of D_vu r = F (paper Alg. 1).

    F: (..., nl, 6, nt) assembled RHS (interior terms only);
    r_surf: (..., 3, nt) Dirichlet surface value (paper eq. 8 on Gamma_s).
    """
    g = _minv_faces(geom, F)
    s = jnp.cumsum(g[..., 0:3, :] + g[..., 3:6, :], axis=-3)  # (.., nl, 3, nt)
    r_b = r_surf[..., None, :, :] - s
    r_t = r_b + 2.0 * g[..., 3:6, :]
    return jnp.concatenate([r_t, r_b], axis=-2)


def solve_w(geom: G.Geom2D, F: jax.Array,
            w_floor: Optional[jax.Array] = None) -> jax.Array:
    """Matrix-free bottom-up solve of D_vd w = F.

    w_floor: (..., 3, nt) bottom impermeability value (0 for the mesh-aligned
    w-tilde; u.grad(b) for the physical w on a z-mesh)."""
    g = _minv_faces(geom, F)
    gsum = g[..., 0:3, :] + g[..., 3:6, :]
    # reverse cumsum over layers: sum_{k>=l}
    s = jnp.flip(jnp.cumsum(jnp.flip(gsum, axis=-3), axis=-3), axis=-3)
    w_t = s if w_floor is None else w_floor[..., None, :, :] + s
    w_b = w_t - 2.0 * g[..., 0:3, :]
    return jnp.concatenate([w_t, w_b], axis=-2)


# ---------------------------------------------------------------------------
# Weighted 3x3 horizontal mass blocks:  WM[g]_ij = sum_q (A/3) phi_i phi_j g_q
# ---------------------------------------------------------------------------
def wmass(geom: G.Geom2D, g_qp: jax.Array) -> jax.Array:
    """g at volume qps (..., 3, nt) -> blocks (..., 3, 3, nt)."""
    return jnp.einsum("qi,qj,...qt->...ijt", G._PHI_VQ, G._PHI_VQ,
                      g_qp) * (geom.area / 3.0)


def wmass_apply(geom: G.Geom2D, g_qp: jax.Array, v: jax.Array) -> jax.Array:
    """WM[g] @ v without materialising blocks: v (..., 3, nt)."""
    vq = G.vol_interp(v)
    return jnp.einsum("qi,...qt->...it", G._PHI_VQ, g_qp * vq) * (geom.area / 3.0)


# ---------------------------------------------------------------------------
# Block-tridiagonal column operator
# ---------------------------------------------------------------------------
class Blocks(NamedTuple):
    """Column operator blocks, each (nl, 6, 6, nt).

    lo[l] couples layer l to layer l-1 (above), up[l] to layer l+1 (below).
    lo[0] and up[nl-1] are zero."""
    lo: jax.Array
    dg: jax.Array
    up: jax.Array


def mass_blocks(geom: G.Geom2D, jz: jax.Array, nl: int) -> jax.Array:
    """3D prism mass matrix blocks (block-diagonal): (nl, 6, 6, nt).

    M = MZ (x) WM[jz]; jz (3, nt) is constant over layers (sigma grid).
    """
    wm = wmass(geom, G.vol_interp(jz))              # (3, 3, nt)
    blk = jnp.einsum("ab,ijt->aibjt", MZ, wm)       # (2,3,2,3,nt)
    blk = blk.reshape(6, 6, wm.shape[-1])
    return jnp.broadcast_to(blk[None], (nl, 6, 6, blk.shape[-1]))


def mass_apply3d(geom: G.Geom2D, jz: jax.Array, u: jax.Array) -> jax.Array:
    """M u for 3D fields (..., nl, 6, nt) without materialising blocks."""
    ut, ub = u[..., 0:3, :], u[..., 3:6, :]
    wm_t = wmass_apply(geom, G.vol_interp(jz), MZ[0, 0] * ut + MZ[0, 1] * ub)
    wm_b = wmass_apply(geom, G.vol_interp(jz), MZ[1, 0] * ut + MZ[1, 1] * ub)
    return jnp.concatenate([wm_t, wm_b], axis=-2)


def mass_solve3d(geom: G.Geom2D, jz: jax.Array, r: jax.Array) -> jax.Array:
    """M^{-1} r: MZ^{-1} (x) WM[jz]^{-1}; WM[jz]^{-1} via 3x3 solve."""
    # MZ^{-1} = [[2,-1],[-1,2]]
    rt, rb = r[..., 0:3, :], r[..., 3:6, :]
    st = 2.0 * rt - rb
    sb = -rt + 2.0 * rb
    wm = wmass(geom, G.vol_interp(jz))               # (3,3,nt)
    wmT = jnp.moveaxis(wm, -1, 0)                    # (nt,3,3)
    def solve3(v):
        vT = jnp.moveaxis(v, -1, -2)                 # (..., 3, nt)->(...,nt,3)
        out = jnp.linalg.solve(wmT, vT[..., None])[..., 0]
        return jnp.moveaxis(out, -1, -2)
    return jnp.concatenate([solve3(st), solve3(sb)], axis=-2)


def sigma3_horizontal(geom: G.Geom2D, H: jax.Array, nl: int,
                      N0: float = 5.0, o: int = 1, d: int = 3) -> jax.Array:
    """Interior-penalty coefficient on horizontal faces (paper eq. 19):
    sigma_d = N0(o+1)(o+d) / (2 d L), L = average prism height."""
    L = H / nl                                        # (3, nt)
    return N0 * (o + 1) * (o + d) / (2.0 * d * L)


def assemble_vertical_operator(
        geom: G.Geom2D,
        nl: int,
        jz: jax.Array,           # (3, nt)
        wrel_nodes: jax.Array,   # (nl, 6, nt): w~ - w_m at prism nodes
        wface: jax.Array,        # (nl+1, 3, nt): advective speed at interfaces
                                 #   (w~_t of the layer below the interface - w_m);
                                 #   row 0 = free surface, row nl = floor.
        kappa: jax.Array,        # (nl, 6, nt): implicit vertical visc/diff
        H: jax.Array,            # (3, nt) for the penalty length scale
        drag_coeff: Optional[jax.Array] = None,  # (3, nt) linearised bottom
                                 # drag Cd|u_bot| (momentum only)
        ) -> Blocks:
    """Assemble F_3D^v as block-tridiagonal blocks (paper eq. 18).

    Sign convention: F_3D^v(u) = (lo, dg, up) @ u appears on the RHS of the
    momentum/tracer equations; the implicit system is (M - dt*A) u1 = rhs.
    """
    nt = jz.shape[-1]
    dt_ = jz.dtype
    dg = jnp.zeros((nl, 6, 6, nt), dt_)
    lo = jnp.zeros((nl, 6, 6, nt), dt_)
    up = jnp.zeros((nl, 6, 6, nt), dt_)
    jz_q = G.vol_interp(jz)                         # (3qp, nt)
    area3 = geom.area / 3.0

    def wm(g_qp):                                   # (..., 3qp, nt)->(...,3,3,nt)
        return jnp.einsum("qi,qj,...qt->...ijt", G._PHI_VQ, G._PHI_VQ,
                          g_qp) * area3

    # --- 1. advection volume: + s_a * sum_qz phi_z^b(qz) WM[wrel(qz)] -------
    # wrel at (qz, qh): interp vertical then horizontal
    wt_q = G.vol_interp(wrel_nodes[:, 0:3, :])      # (nl, 3qp, nt)
    wb_q = G.vol_interp(wrel_nodes[:, 3:6, :])
    for iz in range(2):                             # vertical Gauss points
        wq = PHI_Z[iz, 0] * wt_q + PHI_Z[iz, 1] * wb_q   # (nl, 3qp, nt)
        blk = wm(wq)                                # (nl, 3, 3, nt)
        for a in range(2):
            for b_ in range(2):
                coef = SZ[a] * PHI_Z[iz, b_]
                dg = dg.at[:, 3 * a:3 * a + 3, 3 * b_:3 * b_ + 3, :].add(
                    coef * blk)

    # --- 3. viscosity volume: - s_a s_b WM[sum_qz kappa(qz)/jz] -------------
    kt_q = G.vol_interp(kappa[:, 0:3, :])
    kb_q = G.vol_interp(kappa[:, 3:6, :])
    ksum_q = (PHI_Z[0, 0] + PHI_Z[1, 0]) * kt_q + (PHI_Z[0, 1] + PHI_Z[1, 1]) * kb_q
    blk_visc = wm(ksum_q / jz_q)                    # (nl, 3, 3, nt)
    for a in range(2):
        for b_ in range(2):
            dg = dg.at[:, 3 * a:3 * a + 3, 3 * b_:3 * b_ + 3, :].add(
                -SZ[a] * SZ[b_] * blk_visc)

    # --- interface terms (k = 1..nl-1 interior) ------------------------------
    # advective upwind flux, viscosity consistency mean, interior penalty
    Wq = G.vol_interp(wface)                        # (nl+1, 3qp, nt)
    up_mask = (Wq > 0).astype(dt_)                  # upwind = from below
    k_bot_above = G.vol_interp(kappa[:, 3:6, :])    # (nl, 3qp, nt) at own bottom
    k_top_below = G.vol_interp(kappa[:, 0:3, :])    # (nl, 3qp, nt) at own top
    sig = G.vol_interp(sigma3_horizontal(geom, H, nl))  # (3qp, nt)

    # interior interfaces k=1..nl-1: between layer k-1 (above) and k (below)
    Wk = Wq[1:nl]                                   # (nl-1, 3qp, nt)
    upk = up_mask[1:nl]
    blk_below = wm(Wk * upk)                        # coupling to u_{k, top}
    blk_above = wm(Wk * (1 - upk))                  # coupling to u_{k-1, bot}
    # test (k, top) rows [n_z=+1]: -flux
    dg = dg.at[1:, 0:3, 0:3, :].add(-blk_below)
    lo = lo.at[1:, 0:3, 3:6, :].add(-blk_above)
    # test (k-1, bot) rows [n_z=-1]: +flux
    up = up.at[:-1, 3:6, 0:3, :].add(blk_below)
    dg = dg.at[:-1, 3:6, 3:6, :].add(blk_above)

    # surface interface k=0: u^up == interior (layer 0 top) for both signs
    W0 = Wq[0]
    blk0 = wm(W0)
    dg = dg.at[0, 0:3, 0:3, :].add(-blk0)
    # floor interface k=nl: speed is 0 by impermeability (wface[nl] == 0);
    # assemble anyway for generality (upwind = from above = own bottom)
    Wn = Wq[nl]
    blkn = wm(Wn)
    dg = dg.at[nl - 1, 3:6, 3:6, :].add(blkn)

    # viscosity consistency at interior interfaces: mean of kappa d_zeta u / jz
    # from both sides; factor 1/2 (mean) * 1/2 (d_zeta of P1) = 1/4
    kb = wm(k_bot_above[:nl - 1] / jz_q / 4.0)      # (nl-1,3,3,nt) above side
    kt = wm(k_top_below[1:] / jz_q / 4.0)           # below side
    # test (k, top) [+]: + {.}  => +kt*(u_t^k - u_b^k)/.. +kb*(u_t^{k-1}-u_b^{k-1})
    dg = dg.at[1:, 0:3, 0:3, :].add(kt)
    dg = dg.at[1:, 0:3, 3:6, :].add(-kt)
    lo = lo.at[1:, 0:3, 0:3, :].add(kb)
    lo = lo.at[1:, 0:3, 3:6, :].add(-kb)
    # test (k-1, bot) [-]: - {.}
    up = up.at[:-1, 3:6, 0:3, :].add(-kt)
    up = up.at[:-1, 3:6, 3:6, :].add(kt)
    dg = dg.at[:-1, 3:6, 0:3, :].add(-kb)
    dg = dg.at[:-1, 3:6, 3:6, :].add(kb)

    # interior penalty: -sigma {kappa} [[u]] on interface k
    kmean = 0.5 * (k_bot_above[:nl - 1] + k_top_below[1:])  # (nl-1, 3qp, nt)
    pen = wm(sig * kmean) * 0.5                     # the [[.]] carries 1/2
    # test (k, top): -pen*(u_t^k - u_b^{k-1})
    dg = dg.at[1:, 0:3, 0:3, :].add(-pen)
    lo = lo.at[1:, 0:3, 3:6, :].add(pen)
    # test (k-1, bot): -pen*(u_b^{k-1} - u_t^k)
    dg = dg.at[:-1, 3:6, 3:6, :].add(-pen)
    up = up.at[:-1, 3:6, 0:3, :].add(pen)

    # bottom drag (momentum): - WM[Cd|u|] on the floor nodes
    if drag_coeff is not None:
        blk_drag = wm(G.vol_interp(drag_coeff))
        dg = dg.at[nl - 1, 3:6, 3:6, :].add(-blk_drag)

    return Blocks(lo=lo, dg=dg, up=up)


def implicit_system(M_blocks: jax.Array, A: Blocks, dtau: float) -> Blocks:
    """The vertically-implicit system (M - dt A) as Blocks.

    M_blocks: (nl, 6, 6, nt) mass blocks at the end-of-stage geometry;
    A: the assembled F_3D^v operator.  Used by both the SoA reference solve
    and the cell-layout Pallas path (kernels/ops.block_thomas)."""
    return Blocks(lo=-dtau * A.lo, dg=M_blocks - dtau * A.dg,
                  up=-dtau * A.up)


def blocks_matvec(blocks: Blocks, u: jax.Array) -> jax.Array:
    """Apply the block-tridiagonal operator: u (..., nl, 6, nt)."""
    lo, dg, up = blocks
    out = jnp.einsum("lijt,...ljt->...lit", dg, u)
    out = out.at[..., 1:, :, :].add(
        jnp.einsum("lijt,...ljt->...lit", lo[1:], u[..., :-1, :, :]))
    out = out.at[..., :-1, :, :].add(
        jnp.einsum("lijt,...ljt->...lit", up[:-1], u[..., 1:, :, :]))
    return out


def block_thomas_solve(blocks: Blocks, rhs: jax.Array) -> jax.Array:
    """Solve the block-tridiagonal system; rhs (k, nl, 6, nt) for k RHS
    components (momentum solves u,v together; tracers T,S together).

    Scanned forward elimination with batched 6x6 LU solves over columns —
    the JAX reference for the Pallas `column_solve` kernel (paper §2.4).
    """
    lo, dg, up = blocks
    k, nl, _, nt = rhs.shape
    # reshape to (nl, nt, 6, 6) / (nl, nt, 6, k) for batched linalg
    loT = jnp.moveaxis(lo, -1, 1)
    dgT = jnp.moveaxis(dg, -1, 1)
    upT = jnp.moveaxis(up, -1, 1)
    bT = jnp.moveaxis(jnp.moveaxis(rhs, 0, -1), -2, 1)   # (nl, nt, 6, k)

    def fwd(carry, inp):
        C_prev, y_prev = carry                           # (nt,6,6), (nt,6,k)
        L, D, U, b = inp
        S = D - L @ C_prev
        Cy = jnp.linalg.solve(S, jnp.concatenate([U, b - L @ y_prev], axis=-1))
        C = Cy[..., :6]
        y = Cy[..., 6:]
        return (C, y), (C, y)

    C0 = jnp.zeros((nt, 6, 6), rhs.dtype)
    y0 = jnp.zeros((nt, 6, k), rhs.dtype)
    _, (Cs, ys) = jax.lax.scan(fwd, (C0, y0), (loT, dgT, upT, bT))

    def bwd(x_next, inp):
        C, y = inp
        x = y - C @ x_next
        return x, x

    _, xs = jax.lax.scan(bwd, jnp.zeros((nt, 6, k), rhs.dtype), (Cs, ys),
                         reverse=True)
    # (nl, nt, 6, k) -> (k, nl, 6, nt)
    return jnp.moveaxis(jnp.moveaxis(xs, 1, -1), -2, 0)


def blocks_dense(blocks: Blocks) -> jax.Array:
    """Materialise (nt, nl*6, nl*6) dense matrices (tests only)."""
    lo, dg, up = blocks
    nl, _, _, nt = dg.shape
    A = jnp.zeros((nt, nl * 6, nl * 6), dg.dtype)
    for l in range(nl):
        A = A.at[:, l * 6:(l + 1) * 6, l * 6:(l + 1) * 6].set(
            jnp.moveaxis(dg[l], -1, 0))
        if l > 0:
            A = A.at[:, l * 6:(l + 1) * 6, (l - 1) * 6:l * 6].set(
                jnp.moveaxis(lo[l], -1, 0))
        if l < nl - 1:
            A = A.at[:, l * 6:(l + 1) * 6, (l + 1) * 6:(l + 2) * 6].set(
                jnp.moveaxis(up[l], -1, 0))
    return A
