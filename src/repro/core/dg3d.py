"""3D DG operators on the prismatic mesh (paper SI §S2–S3).

Provides:
  * prism volume / lateral-face quadrature helpers (tensor-product P1),
  * the RHS of the hydrostatic pressure gradient r (SI eq. 11),
  * the RHS of the modified continuity equation for w-tilde (SI eq. 13),
  * the horizontal momentum / tracer flux F_3D^h (SI eq. 17 / 20),
  * the consistent 3D transport q-bar (paper eq. 18),
  * Smagorinsky / Okubo horizontal mixing coefficients.

Consistency refinement (DESIGN.md §5, `exact_consistency`): the 3D lateral
advective flux is  n.{q} + {Jz/H} * (Fbar_edge - n.{Qbar}),  where Fbar_edge
is the stage-weighted time-average of the *actual* 2D free-surface edge flux
accumulated during the external burst.  Its vertical sum telescopes to
Fbar_edge exactly, making tracer constancy and mass consistency hold to
machine precision (the paper's literal form  n.{q} + {Jz/H} c+ [[eta]]  is
recovered with `exact_consistency=False`).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import geometry as G
from .extrusion import VGrid, VertGeom, vsum_dofs
from .vertical import PHI_Z, SZ
from ..kernels import dispatch as _dispatch

RHO0 = 1025.0


# ---------------------------------------------------------------------------
# Prism quadrature helpers
# ---------------------------------------------------------------------------
def zinterp(f: jax.Array) -> jax.Array:
    """Vertical interp of a prism field to the 2 Gauss-zeta levels.

    (..., nl, 6, nt) -> (..., nl, 2qz, 3, nt), nodal in horizontal."""
    ft = f[..., :, 0:3, :]
    fb = f[..., :, 3:6, :]
    return (ft[..., :, None, :, :] * PHI_Z[:, 0][:, None, None]
            + fb[..., :, None, :, :] * PHI_Z[:, 1][:, None, None])


def vol3d_scatter(geom: G.Geom2D, g: jax.Array) -> jax.Array:
    """Prism volume integral against all 6 test functions.

    g: (..., nl, 2qz, 3qh, nt) integrand (without Jacobians; the A/3 weight
    and unit vertical Gauss weights are applied here) -> (..., nl, 6, nt)."""
    # horizontal scatter for each (qz): (..., nl, 2qz, 3nodes, nt)
    s = jnp.einsum("qn,...zqt->...znt", G._PHI_VQ, g) * (geom.area / 3.0)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    return jnp.concatenate([top, bot], axis=-2)


def lat_interp(f: jax.Array) -> jax.Array:
    """Interior values at lateral-face qps.

    (..., nl, 6, nt) -> (..., nl, 2qz, 3edge, 2qs, nt)."""
    fz = zinterp(f)                                   # (..., nl, 2qz, 3, nt)
    return G.edge_interp(fz)                          # edge interp on last axes


def lat_interp_ext(geom: G.Geom2D, f: jax.Array) -> jax.Array:
    fz = zinterp(f)
    return G.edge_interp_ext(geom, fz)


def edge_ext_nodal6(geom: G.Geom2D, f: jax.Array) -> jax.Array:
    """Neighbour nodal values per lateral edge — ONE gather at nodal width.

    f: (..., 6, nt) -> (..., 3edge, 2[a|b], 2[top|bot], nt): for edge e the
    neighbour's values at the nodes facing my edge nodes a/b, on the top and
    bottom faces.  The qp-level exterior states (`lat_interp_ext`) are a
    linear map of these (see `lat_ext_from_nodal`), so the fused pipeline
    gathers once here instead of at 12-qp width."""
    ft, fb = f[..., 0:3, :], f[..., 3:6, :]
    ta = ft[..., geom.ext_na, geom.ext_tri]
    tb = ft[..., geom.ext_nb, geom.ext_tri]
    ba = fb[..., geom.ext_na, geom.ext_tri]
    bb = fb[..., geom.ext_nb, geom.ext_tri]
    return jnp.stack([jnp.stack([ta, ba], axis=-2),
                      jnp.stack([tb, bb], axis=-2)], axis=-3)


def own_nodal6(f: jax.Array) -> jax.Array:
    """Own nodal values in the edge_ext_nodal6 layout (identity 'gather');
    used to blend forced open-boundary values at nodal level."""
    ft, fb = f[..., 0:3, :], f[..., 3:6, :]
    ta, tb = ft[..., G.EDGE_A, :], ft[..., G.EDGE_B, :]
    ba, bb = fb[..., G.EDGE_A, :], fb[..., G.EDGE_B, :]
    return jnp.stack([jnp.stack([ta, ba], axis=-2),
                      jnp.stack([tb, bb], axis=-2)], axis=-3)


def lat_ext_from_nodal(fx: jax.Array) -> jax.Array:
    """Exterior values at lateral qps from the nodal neighbour gather.

    fx: (..., nl, 3edge, 2[a|b], 2[top|bot], nt)
    -> (..., nl, 2qz, 3edge, 2qs, nt), identical to `lat_interp_ext` of the
    ungathered field (zeta-interp and the gather commute node-wise).
    Written as broadcast arithmetic (axis-insertion style, like
    `edge_interp`) — the einsum form lowers to transpose-heavy HLO."""
    ft, fb = fx[..., 0, :], fx[..., 1, :]           # (..., nl, 3e, 2j, nt)
    fz = (ft[..., None, :, :, :] * PHI_Z[:, 0][:, None, None, None]
          + fb[..., None, :, :, :] * PHI_Z[:, 1][:, None, None, None])
    fa, fb2 = fz[..., 0, :], fz[..., 1, :]          # (..., nl, 2qz, 3e, nt)
    return (fa[..., :, None, :] * G._PHIA[:, None]
            + fb2[..., :, None, :] * G._PHIB[:, None])


def reflect_nodal(geom: G.Geom2D, fx_pair: jax.Array) -> jax.Array:
    """Free-slip wall reflection applied to a velocity pair's nodal
    neighbour gather (2, ..., 3edge, 2, 2, nt).  Normals are constant per
    edge, so reflecting nodally then interpolating equals reflecting the
    interpolated qp states (`reflect_pair`)."""
    nx = geom.edge_nx[:, None, None, :]
    ny = geom.edge_ny[:, None, None, :]
    wall = geom.wall[:, None, None, :]
    un = fx_pair[0] * nx + fx_pair[1] * ny
    return jnp.stack([fx_pair[0] - 2 * wall * un * nx,
                      fx_pair[1] - 2 * wall * un * ny])


def lat_scatter(geom: G.Geom2D, g: jax.Array) -> jax.Array:
    """Lateral-face integral against all 6 test functions.

    g: (..., nl, 2qz, 3edge, 2qs, nt) integrand (Jl edge-length jacobian is
    applied inside; vertical Gauss weights are 1) -> (..., nl, 6, nt)."""
    s = G.edge_scatter(geom, g)                       # (..., nl, 2qz, 3, nt)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    return jnp.concatenate([top, bot], axis=-2)


def iso_grad(geom: G.Geom2D, f_qz: jax.Array) -> jax.Array:
    """Iso-zeta horizontal gradient from nodal-at-qz values.

    f_qz: (..., nl, 2qz, 3, nt) -> (..., nl, 2qz, 2comp, nt)."""
    return jnp.einsum("...nt,ndt->...dt", f_qz, geom.dphi)


# ---------------------------------------------------------------------------
# Boundary ghosts for 3D lateral faces
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LateralBC:
    """How to build ghost values on WALL / OPEN boundary faces."""
    reflect: bool = False                  # True for velocity components
    open_value: Optional[jax.Array] = None  # (..., nl, 6, nt) forced field


def lat_states(geom: G.Geom2D, f: jax.Array, bc: LateralBC = LateralBC()):
    """(int, ext) values at lateral qps with BCs applied.

    For vector fields pass components separately and use `reflect_pair`."""
    fi = lat_interp(f)
    fe = lat_interp_ext(geom, f)
    if bc.open_value is not None:
        openb = geom.openb[None, :, None, :]
        fo = lat_interp(bc.open_value)
        fe = fe * (1 - openb) + fo * openb
    return fi, fe


def reflect_pair(geom: G.Geom2D, uxe: jax.Array, uye: jax.Array):
    """Apply free-slip wall reflection to exterior velocity values at lateral
    qps (gathered ext == int on boundaries, so reflecting gives the ghost)."""
    nx = geom.edge_nx[:, None, :]
    ny = geom.edge_ny[:, None, :]
    wall = geom.wall[None, :, None, :]
    un = uxe * nx + uye * ny
    return (uxe - 2 * wall * un * nx, uye - 2 * wall * un * ny)


# ---------------------------------------------------------------------------
# Consistent 3D transport (paper eq. 18 + §2.5)
# ---------------------------------------------------------------------------
def transport_from_velocity(vge: VertGeom, ux: jax.Array, uy: jax.Array):
    """q = J_z u projected (nodally) to the linear basis: (2, nl, 6, nt)."""
    jz6 = jnp.concatenate([vge.jz, vge.jz], axis=-2)   # (6, nt)
    return jnp.stack([ux * jz6, uy * jz6])


def consistent_transport(vge: VertGeom, ux, uy, qbar_x2d, qbar_y2d, nl: int):
    """q-bar: nodal J_z u corrected so that the sum over vertical DOFs equals
    the externally-averaged 2D transport Q-bar exactly (paper eq. 18):
    the column-wise defect is distributed uniformly over the 2*nl DOFs."""
    q = transport_from_velocity(vge, ux, uy)
    def fix(qc, Q2d):
        d = (Q2d - vsum_dofs(qc)) / (2.0 * nl)         # (3, nt)
        d6 = jnp.concatenate([d, d], axis=-2)          # (6, nt)
        return qc + d6[None]
    return jnp.stack([fix(q[0], qbar_x2d), fix(q[1], qbar_y2d)])


# ---------------------------------------------------------------------------
# Lateral advective flux speed (per lateral qp)
# ---------------------------------------------------------------------------
class LateralFlux(NamedTuple):
    speed: jax.Array     # (nl, 2qz, 3, 2qs, nt) signed normal flux speed
    upwind: jax.Array    # same shape, 1.0 where interior side is upwind


def lateral_flux_speed(geom: G.Geom2D, vge: VertGeom, vg: VGrid,
                       qx: jax.Array, qy: jax.Array,
                       eta: jax.Array, b2d: jax.Array,
                       fbar_edge: Optional[jax.Array] = None,
                       qbar2d: Optional[tuple] = None,
                       h_min: float = 0.05, cache=None) -> LateralFlux:
    """Normal advective flux speed at lateral qps.

    paper form:   n.{q} + {Jz/H} c+ [[eta]]          (fbar_edge=None)
    exact form:   n.{q} + {Jz/H} (Fbar - n.{Qbar})   (fbar_edge given)
    Wall faces: reflected ghost -> n.{q} = 0, [[eta]]=0 -> speed 0.

    cache: optional per-stage EdgeCache (core/horizontal.py) supplying the
    field-independent {Jz/H} coefficient and eta/H edge states, so only the
    transport itself is gathered here (once per transport per stage).
    """
    nx = geom.edge_nx[:, None, :]
    ny = geom.edge_ny[:, None, :]
    qxi, qxe = lat_interp(qx), lat_interp_ext(geom, qx)
    qyi, qye = lat_interp(qy), lat_interp_ext(geom, qy)
    qxe, qye = reflect_pair(geom, qxe, qye)
    mean_qn = 0.5 * ((qxi + qxe) * nx + (qyi + qye) * ny)

    # {Jz/H} at lateral qps — constant 1/(2 nl) on the uniform sigma grid,
    # computed from fields for generality
    if cache is not None:
        alpha = cache.alpha[None, None]
    else:
        a = vge.jz / jnp.maximum(vge.H, h_min)         # (3, nt)
        ai = G.edge_interp(a)
        ae = G.edge_interp_ext(geom, a)
        alpha = 0.5 * (ai + ae)                        # (3, 2qs, nt)
        alpha = alpha[None, None]                      # bcast (nl, qz)

    if fbar_edge is not None:
        Qbx, Qby = qbar2d
        Qxi, Qxe = G.edge_interp(Qbx), G.edge_interp_ext(geom, Qbx)
        Qyi, Qye = G.edge_interp(Qby), G.edge_interp_ext(geom, Qby)
        # same wall reflection as the 2D mode applied to Q ghosts
        nx2, ny2 = geom.edge_nx[:, None, :], geom.edge_ny[:, None, :]
        wall2 = geom.wall[:, None, :]
        Qn_e = Qxe * nx2 + Qye * ny2
        Qxe = Qxe - 2 * wall2 * Qn_e * nx2
        Qye = Qye - 2 * wall2 * Qn_e * ny2
        mean_Qn = 0.5 * ((Qxi + Qxe) * nx2 + (Qyi + Qye) * ny2)  # (3,2qs,nt)
        corr = fbar_edge - mean_Qn
        speed = mean_qn + alpha * corr[None, None]
    else:
        if cache is not None:
            # vge.H == max(eta + b2d, h_min) (layer_geometry, same h_min)
            Hi, He = cache.H_int, cache.H_ext
            ei, ee = cache.eta_int, cache.eta_ext
        else:
            H2 = jnp.maximum(eta + b2d, h_min)
            Hi, He = G.edge_interp(H2), G.edge_interp_ext(geom, H2)
            ei, ee = G.edge_interp(eta), G.edge_interp_ext(geom, eta)
        c_plus = jnp.sqrt(G.G_GRAV * jnp.maximum(Hi, He))
        jump_eta = 0.5 * (ei - ee) * (1.0 - geom.wall[:, None, :])
        speed = mean_qn + alpha * (c_plus * jump_eta)[None, None]
    return LateralFlux(speed=speed, upwind=(speed > 0).astype(speed.dtype))


# ---------------------------------------------------------------------------
# Generic horizontal advection-diffusion (momentum & tracers share this)
# ---------------------------------------------------------------------------
class FieldStates(NamedTuple):
    """Field-dependent interpolations of one advected field set — everything
    `horizontal_advdiff` needs that depends on neither the flux nor the
    mixing coefficient.  The momentum prediction and momentum update calls
    interpolate the SAME velocity fields, so the stepper builds this once
    per field set per stage and shares it (core/horizontal.py)."""
    fq: jax.Array        # (k, nl, 2qz, 3, nt)      zeta-interp
    fqq: jax.Array       # (k, nl, 2qz, 3qh, nt)    vol-quad values
    fi: jax.Array        # (k, nl, 2qz, 3, 2qs, nt) interior lateral states
    fe: jax.Array        # same, exterior (post-BC)
    fx: Optional[jax.Array]  # (k, nl, 3, 2, 2, nt) nodal ext gather (post-
                             # BC) — nodal path only; feeds the Pallas kernel
    gradf: jax.Array     # (k, nl, 2qz, 2, nt)      iso-zeta gradient
    gno: jax.Array       # (k, nl, 2qz, 3e, nt)     interior normal gradient
    gradf_e: jax.Array   # same, exterior


def field_states(geom: G.Geom2D, f: jax.Array, bc_reflect: bool = False,
                 open_values: Optional[jax.Array] = None,
                 nodal: bool = True) -> FieldStates:
    """Build the FieldStates of (k, nl, 6, nt) fields.

    bc_reflect: the first two components are the horizontal velocity vector
    (free-slip wall reflection of the exterior states).

    nodal=True (fused path) builds the exterior states from ONE neighbour
    gather at nodal width with the BC fixups applied nodally — they are
    linear, so the qp states match the qp-level construction to fp
    reassociation — and keeps the gather (`fx`) for the Pallas lateral-flux
    kernel.  nodal=False reproduces the seed qp-level construction verbatim
    (the equivalence oracle)."""
    k = f.shape[0]
    fq = zinterp(f)                                   # (k, nl, 2qz, 3, nt)
    fqq = G.vol_interp(fq)                            # (k, nl, 2qz, 3qh, nt)
    fi = lat_interp(f)                                # (k, nl, 2qz, 3, 2qs, nt)
    if nodal:
        fx = edge_ext_nodal6(geom, f)                 # (k, nl, 3, 2, 2, nt)
        if bc_reflect:
            assert k >= 2
            fx = jnp.concatenate([reflect_nodal(geom, fx[:2]), fx[2:]])
        if open_values is not None:
            openb = geom.openb[:, None, None, :]
            fx = fx * (1 - openb) + own_nodal6(open_values) * openb
        fe = lat_ext_from_nodal(fx)
    else:
        fx = None
        fe = lat_interp_ext(geom, f)
        if bc_reflect:
            assert k >= 2
            fxe, fye = reflect_pair(geom, fe[0], fe[1])
            fe = jnp.concatenate([jnp.stack([fxe, fye]), fe[2:]])
        if open_values is not None:
            openb = geom.openb[None, :, None, :]
            fo = lat_interp(open_values)
            fe = fe * (1 - openb) + fo * openb
    gradf = iso_grad(geom, fq)                        # (k, nl, 2qz, 2, nt)
    gno = jnp.einsum("...zdt,edt->...zet", gradf,
                     jnp.stack([geom.edge_nx, geom.edge_ny], axis=1))
    gradf_e = _gather_ext_grad(geom, gradf)           # (k, nl, 2qz, 3e, nt)
    return FieldStates(fq=fq, fqq=fqq, fi=fi, fe=fe, fx=fx,
                       gradf=gradf, gno=gno, gradf_e=gradf_e)


def horizontal_advdiff(geom: G.Geom2D, vge: VertGeom, nl: int,
                       f: jax.Array,               # (k, nl, 6, nt) fields
                       qx: jax.Array, qy: jax.Array,  # (nl, 6, nt) transport
                       flux: LateralFlux,
                       nu_h: jax.Array,            # (nl, 6, nt) horiz. mixing
                       bc_reflect: bool = False,   # True for velocity
                       open_values: Optional[jax.Array] = None,
                       cache=None, tcache=None, fcache=None,
                       backend="ref") -> jax.Array:
    """Horizontal advection + along-sigma diffusion terms of F_3D^h / eq. 20.

    Returns (k, nl, 6, nt) RHS contributions (not mass-inverted).

    cache / tcache / fcache (core/horizontal.py) supply the per-stage
    interpolations: field-independent edge/volume states, vol-quad
    transport, and the FieldStates of f.  When fcache is given,
    bc_reflect/open_values are ignored (already baked in).  Without caches
    everything is recomputed per call — the seed path, the equivalence
    oracle.  The lateral advective term runs through the fused Pallas
    kernel (kernels/horizontal_flux.py) when the FieldStates carry the
    nodal gather and ``backend`` resolves to a kernel backend.
    """
    if fcache is None:
        fcache = field_states(geom, f, bc_reflect=bc_reflect,
                              open_values=open_values,
                              nodal=cache is not None)
    adv = horizontal_advection(geom, vge, nl, f, qx, qy, flux,
                               tcache=tcache, fcache=fcache, backend=backend)
    diff = horizontal_diffusion(geom, vge, nl, f, nu_h,
                                cache=cache, fcache=fcache)
    return adv + diff


def horizontal_advection(geom: G.Geom2D, vge: VertGeom, nl: int,
                         f: jax.Array, qx: jax.Array, qy: jax.Array,
                         flux: LateralFlux, bc_reflect: bool = False,
                         open_values: Optional[jax.Array] = None,
                         tcache=None, fcache=None,
                         backend="ref") -> jax.Array:
    """Flux-dependent half of `horizontal_advdiff`: volume advection +
    lateral upwind flux.  This is the part that must run per LateralFlux;
    the diffusion half depends only on (f, nu) and is hoisted by the fused
    stepper to one evaluation per field set per stage.

    bc_reflect/open_values apply only when fcache is not prebuilt (a
    prebuilt FieldStates already carries the BC fixups)."""
    if fcache is None:
        fcache = field_states(geom, f, bc_reflect=bc_reflect,
                              open_values=open_values, nodal=False)

    # --- volume advection: <Jh f (q . phi_z grad(phi_h))> -------------------
    if tcache is not None:
        qxq, qyq = tcache.qxq, tcache.qyq
    else:
        qxq = G.vol_interp(zinterp(qx))               # (nl, 2qz, 3qh, nt)
        qyq = G.vol_interp(zinterp(qy))
    # scatter with gradient test functions: sum_q (A/3) f q . dphi_i phi_z^a
    # (dphi is constant per triangle, so the qh sum factorises)
    gx = (fcache.fqq * qxq).sum(axis=-2)               # (k, nl, 2qz, nt)
    gy = (fcache.fqq * qyq).sum(axis=-2)
    sx = gx[..., None, :] * geom.dphi[:, 0, :]         # (k, nl, 2qz, 3n, nt)
    sy = gy[..., None, :] * geom.dphi[:, 1, :]
    s = (sx + sy) * (geom.area / 3.0)                  # (k, nl, 2qz, 3, nt)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    out = jnp.concatenate([top, bot], axis=-2)         # (k, nl, 6, nt)

    # --- lateral upwind advective flux --------------------------------------
    bk = _dispatch.resolve(backend)
    if fcache.fx is not None and bk is not _dispatch.Backend.REF:
        # fused Pallas kernel: nodal neighbour gather + zeta/edge interp +
        # upwind select + speed multiply + weighted scatter in one pass
        from ..kernels import ops as kops
        lat_adv = kops.lateral_flux_term(geom, f, fcache.fx, flux.speed,
                                         backend=bk)
    else:
        f_up = jnp.where(flux.upwind > 0.5, fcache.fi, fcache.fe)
        lat_adv = lat_scatter(geom, f_up * flux.speed[None])
    return out - lat_adv


def horizontal_diffusion(geom: G.Geom2D, vge: VertGeom, nl: int,
                         f: jax.Array, nu_h: jax.Array,
                         bc_reflect: bool = False,
                         open_values: Optional[jax.Array] = None,
                         cache=None, fcache=None) -> jax.Array:
    """Along-sigma diffusion half of `horizontal_advdiff` (SIP form).

    Depends only on (f, nu_h, jz) — NOT on the transport or flux — so the
    fused stepper evaluates it once per field set per stage (the seed
    evaluated the momentum diffusion twice: prediction and update).

    bc_reflect/open_values apply only when fcache is not prebuilt (a
    prebuilt FieldStates already carries the BC fixups, which enter the
    penalty jump term here)."""
    jz_q = cache.jz_q if cache is not None else G.vol_interp(vge.jz)
    if fcache is None:
        fcache = field_states(geom, f, bc_reflect=bc_reflect,
                              open_values=open_values, nodal=False)

    # volume: -<Jh Jz nu (grad~ phi_i . grad~ f) phi_z^a>
    nu_q = G.vol_interp(zinterp(nu_h))                 # (nl, 2qz, 3qh, nt)
    gradf = fcache.gradf                               # (k, nl, 2qz, 2, nt)
    # against test gradient dphi_i (per qh the integrand is const in qh except
    # nu and jz):  sum_qh (A/3) jz nu  *  dphi_i . gradf
    coef = (nu_q * jz_q).sum(axis=-2) / 3.0 * geom.area  # (nl, 2qz, nt)
    nu_int = lat_interp(nu_h)                          # (nl, 2qz, 3, 2qs, nt)
    nu_ext = lat_interp_ext(geom, nu_h)
    nu_int_b, nu_ext_b = nu_int[None], nu_ext[None]    # bcast over k
    dvol = jnp.einsum("...zdt,ndt,...zt->...znt", gradf, geom.dphi, coef)
    dtop = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], dvol)
    dbot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], dvol)
    out = -jnp.concatenate([dtop, dbot], axis=-2)

    # lateral consistency: + <<phi {Jz nu n.grad~ f} Jl>> (interior faces
    # only).  gno: interior normal gradient per edge; the exterior side
    # gathers the neighbour's (per-triangle-constant) gradient dotted with
    # *our* outward normal (see field_states / _gather_ext_grad).
    if cache is not None:
        nzjz_int, nzjz_ext = cache.jz_int, cache.jz_ext
    else:
        nzjz_int = G.edge_interp(vge.jz)                # (3, 2qs, nt)
        nzjz_ext = G.edge_interp_ext(geom, vge.jz)
    flux_int = fcache.gno[..., None, :] * nu_int_b * nzjz_int[None, None, None]
    flux_ext = (fcache.gradf_e[..., None, :] * nu_ext_b
                * nzjz_ext[None, None, None])
    interior = geom.interior[None, :, None, :]
    mean_flux = 0.5 * (flux_int + flux_ext)

    # lateral penalty: - <<sigma3 {nu} {Jz} [[f]] Jl>>  (interior faces);
    # assembled together with the consistency term in ONE edge scatter
    sig = cache.sigma3 if cache is not None else sigma3_lateral(geom)
    numean = 0.5 * (nu_int_b + nu_ext_b)
    jzmean = (cache.jz_mean if cache is not None
              else 0.5 * (nzjz_int + nzjz_ext))
    jumpf = 0.5 * (fcache.fi - fcache.fe)
    pen = sig[:, None, :] * numean * jzmean[None, None] * jumpf
    return out + lat_scatter(geom, (mean_flux - pen) * interior)


def _gather_ext_grad(geom: G.Geom2D, gradf: jax.Array) -> jax.Array:
    """Exterior iso-zeta gradient dotted with our outward normal, per edge.

    gradf: (k, nl, 2qz, 2comp, nt) constant-per-triangle gradients.
    Returns (k, nl, 2qz, 3edge, nt): n_ours . grad_ext.
    """
    ge_x = gradf[..., 0, :][..., geom.ext_tri]          # (k,nl,2qz,3,nt)
    ge_y = gradf[..., 1, :][..., geom.ext_tri]
    return ge_x * geom.edge_nx + ge_y * geom.edge_ny


def sigma3_lateral(geom: G.Geom2D, N0: float = 5.0, o: int = 1,
                   d: int = 3) -> jax.Array:
    """Interior-penalty coefficient on lateral faces (eq. 19): L = A/l."""
    L_int = geom.area[None, :] / geom.edge_len          # (3, nt)
    # exterior L: neighbour's area over the same (shared) edge length
    L_ext = geom.area[geom.ext_tri] / geom.edge_len
    return N0 * (o + 1) * (o + d) / (2.0 * d * jnp.minimum(L_int, L_ext))


# ---------------------------------------------------------------------------
# Horizontal mixing coefficients (paper §1.1: Smagorinsky / Okubo)
# ---------------------------------------------------------------------------
def smagorinsky_nu(geom: G.Geom2D, ux: jax.Array, uy: jax.Array,
                   cs: float = 0.1, nu_min: float = 1e-3,
                   nu_max: float = 1e4) -> jax.Array:
    """Smagorinsky horizontal viscosity: nu = (cs)^2 * 2A * |S|.

    |S| from the layer-mean iso-sigma velocity gradients.
    Returns (nl, 6, nt) nodal (constant per element per layer)."""
    um = 0.5 * (ux[:, 0:3, :] + ux[:, 3:6, :])           # (nl, 3, nt)
    vm = 0.5 * (uy[:, 0:3, :] + uy[:, 3:6, :])
    gu = G.grad2d(geom, um)                              # (nl, 2, nt)
    gv = G.grad2d(geom, vm)
    s11, s22 = gu[:, 0], gv[:, 1]
    s12 = 0.5 * (gu[:, 1] + gv[:, 0])
    smag = jnp.sqrt(2.0 * (s11 ** 2 + s22 ** 2 + 2.0 * s12 ** 2))  # (nl, nt)
    nu = jnp.clip(cs ** 2 * (2.0 * geom.area) * smag, nu_min, nu_max)
    return jnp.broadcast_to(nu[:, None, :], (nu.shape[0], 6, nu.shape[1]))


def okubo_kappa(geom: G.Geom2D, nl: int, coef: float = 2.055e-4,
                expo: float = 1.15) -> jax.Array:
    """Okubo (1971) scale-dependent horizontal diffusivity:
    kappa = coef * L^expo with L = sqrt(2A) [m]. Returns (nl, 6, nt)."""
    L = jnp.sqrt(2.0 * geom.area)
    kap = coef * L ** expo
    return jnp.broadcast_to(kap[None, None, :], (nl, 6, kap.shape[0]))


# ---------------------------------------------------------------------------
# Pressure gradient RHS (SI eq. 11) + surface value
# ---------------------------------------------------------------------------
def pressure_gradient_rhs(geom: G.Geom2D, vg: VGrid, vge: VertGeom,
                          rho_p: jax.Array, cache=None) -> tuple:
    """RHS of D_vu r = F and the surface Dirichlet value r_s.

    rho_p: (nl, 6, nt) density anomaly. Returns (F (2, nl, 6, nt), r_s (2,3,nt)).
    cache: optional per-stage EdgeCache supplying the jz interpolations.
    """
    g = G.G_GRAV
    nl = vg.nl
    # volume: +g <phi grad~_h rho' Jh Jz>
    rq = zinterp(rho_p)                                 # (nl, 2qz, 3, nt)
    grho = iso_grad(geom, rq)                           # (nl, 2qz, 2, nt)
    jz_q = cache.jz_q if cache is not None else G.vol_interp(vge.jz)
    # integrand at (qz, qh): g * grho (const per qh) * jz(qh)
    intg = g * grho[:, :, :, None, :] * jz_q[None, None, None]  # (nl,2qz,2,3qh,nt)
    F = vol3d_scatter(geom, jnp.moveaxis(intg, 2, 0))   # (2, nl, 6, nt)

    # interior horizontal interfaces k=1..nl-1:
    # -g <<2 phi n_h [[rho']] |Jh/n_z|>>_top ; n_h|Jh/nz| = -grad(z_k) Jh
    from .extrusion import interface_z
    zi = interface_z(vg, vge)                           # (nl+1, 3, nt)
    gz = G.grad2d(geom, zi)                             # (nl+1, 2, nt)
    rho_top = rho_p[1:, 0:3, :]                         # below iface k=1..nl-1
    rho_bot = rho_p[:-1, 3:6, :]                        # above iface
    jump = 0.5 * (rho_top - rho_bot)                    # (nl-1, 3, nt) [[rho']]
    jq = G.vol_interp(jump)                             # (nl-1, 3qh, nt)
    # face integral: sum_qh (A/3) phi_i * (-2 g [[rho']]) * (-grad z_k)
    term = jnp.einsum("qn,kqt,kdt->dknt", G._PHI_VQ, jq,
                      -gz[1:nl]) * (geom.area / 3.0) * (-2.0 * g)
    # applies to test functions on the top face of layer k (k=1..nl-1)
    F = F.at[:, 1:, 0:3, :].add(term)

    # lateral: -g <<phi n [[rho']] {Jz} Jl>>
    ri = lat_interp(rho_p)
    re = lat_interp_ext(geom, rho_p)
    jumpl = 0.5 * (ri - re) * geom.interior[None, :, None, :]
    if cache is not None:
        jzm = cache.jz_mean                             # (3, 2qs, nt)
    else:
        jzi = G.edge_interp(vge.jz)
        jze = G.edge_interp_ext(geom, vge.jz)
        jzm = 0.5 * (jzi + jze)
    n_ = jnp.stack([geom.edge_nx, geom.edge_ny])        # (2, 3, nt)
    intg_l = (-g) * jumpl[None] * jzm[None, None, None] * n_[:, None, None, :, None, :]
    F = F + lat_scatter(geom, intg_l)

    # surface value: r_s = g rho'(eta) grad_h(eta)
    geta = G.grad2d(geom, vge.eta)                      # (2, nt)
    r_s = g * rho_p[0, 0:3, :][None] * geta[:, None, :]

    # Sign convention: the paper's eq. (8) writes d_z r = +g grad(rho'), but
    # its own eq. (7) derivation gives r(z) = g rho'(eta) grad(eta)
    # + g int_z^eta grad(rho') dz~, i.e. r *grows* with depth for a positive
    # density gradient (deep flow must be pushed from the dense toward the
    # light side by -r/rho0).  The top-down solver D_vu decreases r by
    # Mh^{-1}F per face, so the physically-correct RHS is -F of the form
    # assembled above (validated by test_baroclinic_adjustment).
    return -F, r_s


# ---------------------------------------------------------------------------
# Modified continuity RHS for w-tilde (SI eq. 13)
# ---------------------------------------------------------------------------
def continuity_rhs(geom: G.Geom2D, vge: VertGeom, nl: int,
                   qx: jax.Array, qy: jax.Array,
                   flux: LateralFlux, tcache=None) -> jax.Array:
    """RHS of D_vd w~ = F: volume transport divergence + lateral fluxes.

    Uses the SAME LateralFlux as the tracer/momentum advection so the
    discrete budgets telescope exactly.  tcache reuses the vol-quad
    transport shared with horizontal_advdiff.
    """
    # volume: <q . phi_z grad(phi_h) Jh>
    if tcache is not None:
        qxq, qyq = tcache.qxq, tcache.qyq
    else:
        qxq = G.vol_interp(zinterp(qx))                 # (nl, 2qz, 3qh, nt)
        qyq = G.vol_interp(zinterp(qy))
    sx = jnp.einsum("...zqt,nt->...znt", qxq, geom.dphi[:, 0, :])
    sy = jnp.einsum("...zqt,nt->...znt", qyq, geom.dphi[:, 1, :])
    s = (sx + sy) * (geom.area / 3.0)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    F = jnp.concatenate([top, bot], axis=-2)            # (nl, 6, nt)
    # lateral: - <<phi speed Jl>>
    F = F - lat_scatter(geom, flux.speed)
    return F
