"""2D barotropic ("external") mode: free surface + depth-averaged momentum.

Discretisation follows the paper's SI §S1 exactly:
  * eq (2):  M d(eta)/dt = <Jh grad(phi).Q> - <<phi (n.{Q} + c+ [[eta]]) Jl>> + <phi s Jh>
  * eq (4):  M dQ/dt = -<g phi H grad(eta) Jh> + <<n phi g {H} [[eta]] Jl>>
                        - <<phi c+ [[Q]] Jl>> - <phi (H/rho0) grad(p_atm) Jh>
                        + F_3D->2D
  with the reverse-integration-by-parts well-balanced form
  [[H^2/2]] = {H}[[eta]] (removes the O(H^2 eps_machine) noise, SI §S1.2) and a
  local Lax-Friedrichs dissipation speed c+ = max(c_int, c_ext), c = sqrt(gH).

Boundary conditions (via ghost states on the edge quadrature points):
  WALL: eta_ext = eta_int, Q_ext = Q_int - 2 (Q.n) n   (weak impermeability)
  OPEN: eta_ext = eta_bc(t), Q_ext = Q_int             (radiative forcing)

The external mode driver `run_external` advances m sub-steps of SSPRK(3,3)
inside a single `lax.scan` — one fused compiled program for the whole
barotropic burst.  This is the TPU answer to the paper's §3.3 launch-latency
wall: the per-kernel launch overhead that dominates SLIM's 2D mode on GPUs is
amortised away entirely by tracing (DESIGN.md §5, beyond-paper opt #1).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import geometry as G

RHO0 = 1025.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class State2D:
    eta: jax.Array  # (3, nt)
    qx: jax.Array   # (3, nt)
    qy: jax.Array   # (3, nt)

    def __add__(self, o):
        return State2D(self.eta + o.eta, self.qx + o.qx, self.qy + o.qy)

    def __mul__(self, a):
        return State2D(self.eta * a, self.qx * a, self.qy * a)

    __rmul__ = __mul__


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Forcing2D:
    """External-mode forcing, all optional (None disables the term)."""
    eta_open: Optional[jax.Array] = None   # (3, nt) open-boundary elevation
    patm: Optional[jax.Array] = None       # (3, nt) atmospheric pressure
    tau_x: Optional[jax.Array] = None      # (3, nt) wind stress / rho0
    tau_y: Optional[jax.Array] = None
    source: Optional[jax.Array] = None     # (3, nt) rain/evaporation s


def _edge_states(geom: G.Geom2D, st: State2D, forcing: Forcing2D):
    """Interior/exterior values of (eta, qx, qy) at the edge Gauss points,
    with WALL / OPEN ghost states applied."""
    ei = G.edge_interp(st.eta)
    qxi = G.edge_interp(st.qx)
    qyi = G.edge_interp(st.qy)
    ee = G.edge_interp_ext(geom, st.eta)
    qxe = G.edge_interp_ext(geom, st.qx)
    qye = G.edge_interp_ext(geom, st.qy)

    nx = geom.edge_nx[:, None, :]
    ny = geom.edge_ny[:, None, :]
    wall = geom.wall[:, None, :]
    openb = geom.openb[:, None, :]
    intm = 1.0 - wall - openb

    # WALL ghost: reflect normal transport (gathered ext == int on boundaries)
    qn = qxe * nx + qye * ny
    qx_wall = qxe - 2.0 * qn * nx
    qy_wall = qye - 2.0 * qn * ny
    # OPEN ghost
    if forcing.eta_open is not None:
        eta_open = G.edge_interp(forcing.eta_open)
    else:
        eta_open = ee
    eta_e = intm * ee + wall * ei + openb * eta_open
    qx_e = intm * qxe + wall * qx_wall + openb * qxi
    qy_e = intm * qye + wall * qy_wall + openb * qyi
    return (ei, qxi, qyi), (eta_e, qx_e, qy_e)


def external_rhs(geom: G.Geom2D, b: jax.Array, st: State2D,
                 forcing: Forcing2D = Forcing2D(),
                 f3d2d_x: Optional[jax.Array] = None,
                 f3d2d_y: Optional[jax.Array] = None,
                 h_min: float = 0.05,
                 return_flux: bool = False):
    """Right-hand side d/dt (eta, Q) — already multiplied by M^{-1}.

    With return_flux=True also returns the free-surface edge flux
    (n.{Q} + c+[[eta]]) at the edge Gauss points, (3, 2, nt) — accumulated by
    `run_external` into Fbar_edge for the exact-consistency 3D fluxes."""
    g = G.G_GRAV
    H = jnp.maximum(st.eta + b, h_min)

    (ei, qxi, qyi), (ee, qxe, qye) = _edge_states(geom, st, forcing)
    Hi = ei + G.edge_interp(b)
    He = ee + G.edge_interp(b)  # bathymetry continuous-ish; ghost uses own b
    Hi = jnp.maximum(Hi, h_min)
    He = jnp.maximum(He, h_min)
    nx = geom.edge_nx[:, None, :]
    ny = geom.edge_ny[:, None, :]

    c_plus = jnp.sqrt(g * jnp.maximum(Hi, He))
    jump_eta = 0.5 * (ei - ee)
    jump_qx = 0.5 * (qxi - qxe)
    jump_qy = 0.5 * (qyi - qye)
    mean_qn = 0.5 * ((qxi + qxe) * nx + (qyi + qye) * ny)
    mean_H = 0.5 * (Hi + He)

    # ----- free surface -----------------------------------------------------
    # volume: <grad(phi) . Q>  (Q is P1: mean over qps exact)
    qx_q = G.vol_interp(st.qx)
    qy_q = G.vol_interp(st.qy)
    # sum_q (A/3) * dphi_n . Q(q):
    vol_eta = (geom.area / 3.0) * (
        geom.dphi[:, 0, :] * qx_q.sum(axis=0)
        + geom.dphi[:, 1, :] * qy_q.sum(axis=0))
    eta_edge_flux = mean_qn + c_plus * jump_eta
    edge_eta = G.edge_scatter(geom, eta_edge_flux)
    rhs_eta = vol_eta - edge_eta
    if forcing.source is not None:
        rhs_eta = rhs_eta + G.mass_apply(geom, forcing.source)

    # ----- momentum -----------------------------------------------------------
    # volume: -<g phi H grad(eta)>  (grad(eta) const per tri; H at qps)
    deta = G.grad2d(geom, st.eta)                  # (2, nt)
    H_q = G.vol_interp(H)                          # (3, nt) at qps
    vol_qx = -g * G.vol_scatter(geom, H_q * deta[0][None, :])
    vol_qy = -g * G.vol_scatter(geom, H_q * deta[1][None, :])
    # edges: + <<n phi g {H}[[eta]]>> - <<phi c+ [[Q]]>>
    edge_qx = G.edge_scatter(geom, nx * g * mean_H * jump_eta - c_plus * jump_qx)
    edge_qy = G.edge_scatter(geom, ny * g * mean_H * jump_eta - c_plus * jump_qy)
    rhs_qx = vol_qx + edge_qx
    rhs_qy = vol_qy + edge_qy

    if forcing.patm is not None:
        dp = G.grad2d(geom, forcing.patm)
        rhs_qx = rhs_qx - G.vol_scatter(geom, H_q * dp[0][None, :] / RHO0)
        rhs_qy = rhs_qy - G.vol_scatter(geom, H_q * dp[1][None, :] / RHO0)
    if forcing.tau_x is not None:
        rhs_qx = rhs_qx + G.mass_apply(geom, forcing.tau_x)
        rhs_qy = rhs_qy + G.mass_apply(geom, forcing.tau_y)
    if f3d2d_x is not None:
        rhs_qx = rhs_qx + f3d2d_x
        rhs_qy = rhs_qy + f3d2d_y

    out = State2D(G.minv_apply(geom, rhs_eta),
                  G.minv_apply(geom, rhs_qx),
                  G.minv_apply(geom, rhs_qy))
    if return_flux:
        return out, eta_edge_flux
    return out


def standalone_extra_rhs(geom: G.Geom2D, b: jax.Array, st: State2D,
                         coriolis_f: float = 0.0,
                         bottom_cd: float = 0.0,
                         h_min: float = 0.05) -> State2D:
    """Optional standalone-2D terms the coupled model gets from S3 instead:
    Coriolis -f ez x Q and quadratic bottom drag -Cd |Q| Q / H^2."""
    H = jnp.maximum(st.eta + b, h_min)
    rqx = coriolis_f * st.qy
    rqy = -coriolis_f * st.qx
    if bottom_cd > 0:
        qn = jnp.sqrt(st.qx ** 2 + st.qy ** 2)
        rqx = rqx - bottom_cd * qn * st.qx / H ** 2
        rqy = rqy - bottom_cd * qn * st.qy / H ** 2
    return State2D(jnp.zeros_like(st.eta), rqx, rqy)


def ssprk3_step(rhs_fn: Callable[[State2D], State2D], st: State2D,
                dt: float) -> State2D:
    """Shu-Osher SSPRK(3,3) — the paper's 3-stage explicit RK external mode."""
    k1 = st + dt * rhs_fn(st)
    k2 = 0.75 * st + 0.25 * (k1 + dt * rhs_fn(k1))
    return (1.0 / 3.0) * st + (2.0 / 3.0) * (k2 + dt * rhs_fn(k2))


class ExternalResult(NamedTuple):
    state: State2D
    q_bar_x: jax.Array    # (3, nt) effective time-averaged transport
    q_bar_y: jax.Array
    f2d_x: jax.Array      # (3, nt) momentum input from the external mode
    f2d_y: jax.Array
    fbar_edge: jax.Array  # (3, 2, nt) effective time-averaged eta edge flux


# SSPRK(3,3) effective stage weights: u1 = u0 + h(F0/6 + F1/6 + 2 F2/3)
_SSP_W = (1.0 / 6.0, 1.0 / 6.0, 2.0 / 3.0)


def run_external(geom: G.Geom2D, b: jax.Array, st0: State2D, dt: float,
                 m: int, forcing: Forcing2D = Forcing2D(),
                 f3d2d_x: Optional[jax.Array] = None,
                 f3d2d_y: Optional[jax.Array] = None,
                 coriolis_f: float = 0.0, bottom_cd: float = 0.0,
                 h_min: float = 0.05,
                 exchange_fn: Optional[Callable[[State2D], State2D]] = None,
                 exchange_period: int = 0) -> ExternalResult:
    """Advance the external mode by m sub-steps of dt/m (one fused scan).

    Returns the new state, the momentum increment F2D (paper eq. 6)
        F2D = (Q1 - (Q0 + dt*F3D2D)) / dt,
    and the *stage-weighted* time averages of the transport Qbar (paper eq. 5,
    refined: weights follow the SSPRK3 effective fluxes so the eta update is
    EXACTLY dt * div-flux(Qbar, Fbar_edge)) and of the free-surface edge flux
    Fbar_edge.  These make the 3D advection discretely consistent to machine
    precision (DESIGN.md §5).

    Distributed runs pass `exchange_fn` (halo refresh of the 2D state):
      exchange_period = 0: exchange before every RK-stage RHS (paper §3.3 —
        one halo exchange per 2D kernel iteration; needs a 1-deep halo);
      exchange_period = j>0: exchange once per j sub-steps (communication-
        avoiding; needs a 3j-deep halo, beyond-paper opt #2).
    """
    if f3d2d_x is None:
        f3d2d_x = jnp.zeros_like(st0.qx)
        f3d2d_y = jnp.zeros_like(st0.qy)
    dts = dt / m
    ex = exchange_fn if exchange_fn is not None else (lambda s: s)
    per_stage = exchange_fn is not None and exchange_period == 0

    def rhs(s):
        if per_stage:
            s = ex(s)
        r, eflux = external_rhs(geom, b, s, forcing, f3d2d_x, f3d2d_y, h_min,
                                return_flux=True)
        if coriolis_f != 0.0 or bottom_cd > 0.0:
            r = r + standalone_extra_rhs(geom, b, s, coriolis_f, bottom_cd,
                                         h_min)
        return r, eflux

    def substep(s):
        r0, ef0 = rhs(s)
        s1 = s + dts * r0
        r1, ef1 = rhs(s1)
        s2 = 0.75 * s + 0.25 * (s1 + dts * r1)
        r2, ef2 = rhs(s2)
        s3 = (1.0 / 3.0) * s + (2.0 / 3.0) * (s2 + dts * r2)
        w0, w1, w2 = _SSP_W
        qx_eff = w0 * s.qx + w1 * s1.qx + w2 * s2.qx
        qy_eff = w0 * s.qy + w1 * s1.qy + w2 * s2.qy
        ef_eff = w0 * ef0 + w1 * ef1 + w2 * ef2
        return s3, (qx_eff, qy_eff, ef_eff)

    if exchange_fn is not None and exchange_period > 0:
        assert m % exchange_period == 0, (m, exchange_period)
        def body(s, _):
            s = ex(s)
            accs = []
            for _ in range(exchange_period):   # unrolled burst
                s, acc = substep(s)
                accs.append(acc)
            mean = tuple(sum(a[i] for a in accs) / exchange_period
                         for i in range(3))
            return s, mean
        st1, (qxs, qys, efs) = jax.lax.scan(
            body, st0, None, length=m // exchange_period)
    else:
        st1, (qxs, qys, efs) = jax.lax.scan(
            lambda s, _: substep(s), st0, None, length=m)
    # paper eq. 6: F2D = (Q1 - (Q0 + dt*F3D2D))/dt.  F3D2D enters the RHS as a
    # raw assembled integral (mass-weighted); F2D is a nodal rate, so the
    # subtraction must use the mass-inverted F3D2D.
    f2d_x = (st1.qx - st0.qx) / dt - G.minv_apply(geom, f3d2d_x)
    f2d_y = (st1.qy - st0.qy) / dt - G.minv_apply(geom, f3d2d_y)
    return ExternalResult(st1, qxs.mean(axis=0), qys.mean(axis=0),
                          f2d_x, f2d_y, efs.mean(axis=0))


def cfl_dt(geom: G.Geom2D, b: jax.Array, cfl: float = 0.25) -> float:
    """Explicit gravity-wave CFL time step estimate (static, numpy-side)."""
    import numpy as np
    h = np.sqrt(np.asarray(geom.area))           # element length scale
    c = np.sqrt(G.G_GRAV * np.maximum(np.asarray(b).max(axis=0), 0.05))
    return float((cfl * h / c).min())
