"""DG P1 geometry + quadrature machinery (2D triangles, extruded prisms).

Everything here is JAX-traceable; static mesh data lives in `mesh2d.Mesh2D`
(numpy) and is baked into a `Geom2D` pytree once at setup.

Layout conventions (TPU-minded: triangle index is always the minor axis — it
is the long, contiguous, lane-friendly dimension; see DESIGN.md §2):
  2D scalar field      f     : (3, nt)            [node, tri]
  2D vector field      v     : (2, 3, nt)         [comp, node, tri]
  3D scalar field      T     : (nl, 6, nt)        [layer, node, tri]
  3D vector field      u     : (2, nl, 6, nt)
  edge-quad values           : (3, 2, nt)         [edge, qp, tri]

Quadrature (used uniformly for ALL terms so that discrete consistency —
free-surface vs continuity, tracer constancy — holds exactly):
  * triangle volume: 3 edge-midpoint points, weight A/3 (exact to degree 2)
  * edge: 2-point Gauss (exact to degree 3)
  * vertical: 2-point Gauss on [-1, 1]
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import mesh2d
from .mesh2d import EDGE_NODES, INTERIOR, OPEN, WALL

G_GRAV = 9.81

# local node ids of each local edge
EDGE_A = np.array([0, 1, 2])
EDGE_B = np.array([1, 2, 0])

# 2-point Gauss on s in [0,1]
S_GAUSS = np.array([0.5 - np.sqrt(3) / 6, 0.5 + np.sqrt(3) / 6])
W_GAUSS = np.array([0.5, 0.5])  # times edge length

# 2-point Gauss on zeta in [-1,1] (for vertical integration; weight 1 each)
Z_GAUSS = np.array([-1 / np.sqrt(3), 1 / np.sqrt(3)])
W_ZGAUSS = np.array([1.0, 1.0])

# triangle volume quadrature: edge midpoints, weights A/3
#   PHI_VQ[q, i] = phi_i(x_q)
PHI_VQ = np.array([[0.5, 0.5, 0.0],
                   [0.0, 0.5, 0.5],
                   [0.5, 0.0, 0.5]])
W_VQ = 1.0 / 3.0  # times area

# vertical P1 basis at the 2 Gauss points: row=qp, col=(top, bot)
PHI_ZQ = np.stack([(1 + Z_GAUSS) / 2, (1 - Z_GAUSS) / 2], axis=1)  # (2,2)
DPHI_ZQ = np.array([0.5, -0.5])  # d/dzeta of (top,bot) basis — constant


def _f(x, dtype):
    return jnp.asarray(np.asarray(x), dtype=dtype)


def _i(x):
    return jnp.asarray(np.asarray(x), dtype=jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Geom2D:
    """Static per-triangle geometry + DG connectivity gathers (pytree)."""

    area: jax.Array       # (nt,)
    jh: jax.Array         # (nt,)  = 2*area
    dphi: jax.Array       # (3, 2, nt) physical gradients of P1 basis
    node_x: jax.Array     # (3, nt)
    node_y: jax.Array     # (3, nt)
    edge_len: jax.Array   # (3, nt)
    edge_nx: jax.Array    # (3, nt) outward unit normal
    edge_ny: jax.Array    # (3, nt)
    ext_tri: jax.Array    # (3, nt) int32 — neighbour triangle (self at boundary)
    ext_na: jax.Array     # (3, nt) int32 — neighbour-local node facing my node a
    ext_nb: jax.Array     # (3, nt) int32 — neighbour-local node facing my node b
    wall: jax.Array       # (3, nt) 1.0 on WALL edges
    openb: jax.Array      # (3, nt) 1.0 on OPEN edges

    @property
    def nt(self) -> int:
        return self.area.shape[-1]

    @property
    def interior(self) -> jax.Array:
        return 1.0 - self.wall - self.openb


def geom2d_from_mesh(mesh: mesh2d.Mesh2D, dtype=jnp.float32) -> Geom2D:
    p = mesh.node_xy()                      # (nt, 3, 2)
    area = mesh.areas()                     # (nt,)
    d1 = p[:, 1] - p[:, 0]
    d2 = p[:, 2] - p[:, 0]
    # physical gradients: inverse-transpose of [d1 d2] applied to ref grads
    # ref grads: phi0=(-1,-1), phi1=(1,0), phi2=(0,1)
    det = d1[:, 0] * d2[:, 1] - d1[:, 1] * d2[:, 0]  # = 2A > 0
    # J = [[d1x, d2x],[d1y, d2y]]; J^{-1} = adj(J)/det = [[d2y,-d2x],[-d1y,d1x]]/det
    inv_j = np.stack([
        np.stack([d2[:, 1], -d2[:, 0]], axis=-1),
        np.stack([-d1[:, 1], d1[:, 0]], axis=-1),
    ], axis=1) / det[:, None, None]          # (nt, 2, 2): J^{-1}
    gref = np.array([[-1.0, -1.0], [1.0, 0.0], [0.0, 1.0]])  # (3, 2)
    # physical grad: J^{-T} @ gref_n, i.e. dphi[n,d] = sum_c inv_j[c,d]*gref[n,c]
    dphi = np.einsum("tcd,nc->ndt", inv_j, gref)  # (3, 2, nt)

    # edges
    pa = p[:, EDGE_A]                       # (nt, 3, 2)
    pb = p[:, EDGE_B]
    ev = pb - pa
    elen = np.linalg.norm(ev, axis=-1)      # (nt, 3)
    # outward normal for CCW triangles: rotate edge vector by -90deg
    nx = ev[:, :, 1] / elen
    ny = -ev[:, :, 0] / elen

    # neighbour node matching: my edge (a,b) faces neighbour edge (a',b') with
    # a<->b' and b<->a' (opposite traversal).
    ne = mesh.neigh_edge                    # (nt, 3)
    ext_na = EDGE_NODES[ne, 1]              # b'
    ext_nb = EDGE_NODES[ne, 0]              # a'
    bnd = mesh.edge_type != INTERIOR
    # boundary: ext node = own node (ghost state mirrors interior)
    ext_na = np.where(bnd, EDGE_NODES[np.arange(3)[None, :], 0], ext_na)
    ext_nb = np.where(bnd, EDGE_NODES[np.arange(3)[None, :], 1], ext_nb)

    return Geom2D(
        area=_f(area, dtype),
        jh=_f(2 * area, dtype),
        dphi=_f(dphi, dtype),
        node_x=_f(p[:, :, 0].T, dtype),
        node_y=_f(p[:, :, 1].T, dtype),
        edge_len=_f(elen.T, dtype),
        edge_nx=_f(nx.T, dtype),
        edge_ny=_f(ny.T, dtype),
        ext_tri=_i(mesh.neigh_tri.T),
        ext_na=_i(ext_na.T),
        ext_nb=_i(ext_nb.T),
        wall=_f((mesh.edge_type == WALL).T, dtype),
        openb=_f((mesh.edge_type == OPEN).T, dtype),
    )


# ---------------------------------------------------------------------------
# Elementwise DG operations (2D). All support leading batch dims via vmap-free
# broadcasting: fields may have extra leading axes before (3, nt).
# ---------------------------------------------------------------------------
def grad2d(geom: Geom2D, f: jax.Array) -> jax.Array:
    """Constant per-triangle gradient of a P1 field: (..., 3, nt) -> (..., 2, nt)."""
    return jnp.einsum("...nt,ndt->...dt", f, geom.dphi)


def mass_apply(geom: Geom2D, f: jax.Array) -> jax.Array:
    """M f with M = (A/12)(I + ones): (..., 3, nt)."""
    s = f.sum(axis=-2, keepdims=True)
    return (geom.area / 12.0) * (f + s)


def minv_apply(geom: Geom2D, r: jax.Array) -> jax.Array:
    """M^{-1} r = (12/A)(r - sum(r)/4): (..., 3, nt)."""
    s = r.sum(axis=-2, keepdims=True)
    return (12.0 / geom.area) * (r - 0.25 * s)


def lumped_mass(geom: Geom2D) -> jax.Array:
    """Row-sum lumped mass (A/3 per node): (1, nt) broadcastable."""
    return (geom.area / 3.0)[None, :]


# --- edge quadrature ---------------------------------------------------------
_SQ = jnp.asarray(S_GAUSS)          # (2,)
_PHIA = 1.0 - _SQ                   # basis of node a at qps
_PHIB = _SQ


def edge_interp(f: jax.Array) -> jax.Array:
    """Interior values at the 2 Gauss points of the 3 edges.

    f: (..., 3, nt) nodal -> (..., 3, 2, nt) [edge, qp].
    """
    fa = f[..., EDGE_A, :]          # (..., 3, nt)
    fb = f[..., EDGE_B, :]
    return (fa[..., :, None, :] * _PHIA[:, None]
            + fb[..., :, None, :] * _PHIB[:, None])


def edge_ext_nodal(geom: Geom2D, f: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Neighbour nodal values facing my edge nodes a and b: two (..., 3, nt)."""
    fa = f[..., geom.ext_na, geom.ext_tri]
    fb = f[..., geom.ext_nb, geom.ext_tri]
    return fa, fb


def edge_interp_ext(geom: Geom2D, f: jax.Array) -> jax.Array:
    """Exterior (neighbour) values at my edge Gauss points: (..., 3, 2, nt)."""
    fa, fb = edge_ext_nodal(geom, f)
    return (fa[..., :, None, :] * _PHIA[:, None]
            + fb[..., :, None, :] * _PHIB[:, None])


# scatter tensor: _EDGE_SCATTER[e, q, n] = w_q * phi_n(s_q) on edge e
# (node EDGE_A[e] carries _PHIA, node EDGE_B[e] carries _PHIB, third node 0);
# kept as numpy — its 12 nonzero entries are baked in as trace-time scalars
_EDGE_SCATTER = np.zeros((3, 2, 3))
for _e in range(3):
    _EDGE_SCATTER[_e, :, EDGE_A[_e]] += W_GAUSS * (1.0 - S_GAUSS)
    _EDGE_SCATTER[_e, :, EDGE_B[_e]] += W_GAUSS * S_GAUSS


def edge_scatter(geom: Geom2D, g: jax.Array) -> jax.Array:
    """Assemble edge integrals back onto nodes.

    g: (..., 3, 2, nt) integrand at edge Gauss points (WITHOUT the length
    jacobian). Returns (..., 3, nt): sum_e sum_q w_q * l_e/1 * phi_node(s_q) * g.
    Note: weights W_GAUSS already include the 1/2 of the [0,1]->[s] map, so the
    jacobian factor is just edge_len.

    The (edge, qp) -> node accumulation contracts against the precomputed
    scatter tensor _EDGE_SCATTER, unrolled over its 12 nonzero entries as
    trace-time scalars: this sits inside every lateral term, and both the
    seed per-edge .at[].add chain and a jnp.einsum contraction are ~8-14x
    slower on CPU XLA (the einsum lowers to transpose-heavy HLO; the
    unrolled form fuses into one elementwise pass over the qp array).
    """
    gw = g * geom.edge_len[:, None, :]
    cols = []
    for n in range(3):
        acc = None
        for e in range(3):
            for q in range(2):
                c = float(_EDGE_SCATTER[e, q, n])
                if c != 0.0:
                    term = c * gw[..., e, q, :]
                    acc = term if acc is None else acc + term
        cols.append(acc)
    return jnp.stack(cols, axis=-2)


# --- volume quadrature -------------------------------------------------------
_PHI_VQ = jnp.asarray(PHI_VQ)       # (q=3, node=3)


def vol_interp(f: jax.Array) -> jax.Array:
    """Nodal (..., 3, nt) -> values at the 3 volume qps (..., 3, nt)."""
    return jnp.einsum("qn,...nt->...qt", _PHI_VQ, f)


def vol_scatter(geom: Geom2D, g: jax.Array) -> jax.Array:
    """∫ phi_i g over each triangle, g given at volume qps.

    g: (..., 3, nt) at qps -> (..., 3, nt) nodal coefficients.
    """
    return jnp.einsum("qn,...qt->...nt", _PHI_VQ, g) * (geom.area / 3.0)
