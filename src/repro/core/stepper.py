"""Split-IMEX RK2 time stepper coupling the internal (3D) and external (2D)
modes — the paper's §1.2/§2 scheme (Ishimwe et al. 2023/2025), with the five
components of Figure 2 per stage:

  1. 3D horizontal momentum flux prediction (always explicit) -> F_3D->2D
  2. external mode burst (m sub-steps of SSPRK3)               -> eta, F2D, Qbar
  3. turbulence update (GLS)                                   -> nu_v, kappa_v
  4. momentum update with the 2D correction (vertically implicit on stage 1)
  5. tracer update (same machinery, T & S solved together)

Stage 1 advances t -> t + dt/2 vertically-implicitly; stage 2 re-integrates
t -> t + dt with midpoint fluxes, vertically explicit (paper Fig. 2; for
vertically explicit steps the turbulence update is performed last).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import dg2d, dg3d, eos, horizontal, turbulence, vertical
from . import geometry as G
from ..kernels import ops as kops
from .dg2d import Forcing2D, State2D
from .extrusion import (VGrid, expand2d, layer_geometry, mesh_velocity,
                        node_z, vsum_dofs)

RHO0 = 1025.0


@dataclasses.dataclass(frozen=True)
class OceanConfig:
    """Static model configuration (plain Python; closed over by jit)."""
    nl: int = 8                  # vertical layers
    dt: float = 60.0             # internal (baroclinic) step [s]
    m_2d: int = 20               # external sub-steps per internal step
    coriolis_f: float = 0.0
    cd_bottom: float = 2.5e-3
    cs_smag: float = 0.1
    eos_kind: str = "linear"
    h_min: float = 0.05
    implicit_stage1: bool = True
    exact_consistency: bool = True
    nu_v_bg: float = 1e-4        # background vertical viscosity
    kappa_v_bg: float = 1e-5
    use_gls: bool = True
    halo_exchange_period: int = 0  # 0: per 2D RK stage; j>0: every j substeps
    backend: str = "auto"        # kernel backend (kernels/dispatch.py):
                                 # ref | pallas_interpret | pallas | auto
                                 # (auto: pallas on TPU, interpret on CPU,
                                 #  ref on other accelerators); used by the
                                 # column solvers and the fused lateral-flux
                                 # kernel
    fused_horizontal: bool = True  # per-stage shared interpolation caches +
                                   # k-stacked momentum/tracer advdiff
                                   # (core/horizontal.py); False keeps the
                                   # seed per-call path (equivalence oracle)

    def with_recovery(self, dt_factor: float = 0.5,
                      visc_factor: float = 1.0) -> "OceanConfig":
        """Degraded-mode config for the recovery ladder
        (``runtime/fault_tolerance.SimulationRunner``).

        Scales the internal step ``dt`` by ``dt_factor``; ``m_2d`` is kept,
        so the external sub-step ``dt_2d = dt/m_2d`` scales consistently and
        every CFL number shrinks by the same factor.  ``visc_factor > 1``
        additionally bumps the background vertical mixing (extra damping
        while riding out a blow-up)."""
        return dataclasses.replace(
            self, dt=self.dt * dt_factor,
            nu_v_bg=self.nu_v_bg * visc_factor,
            kappa_v_bg=self.kappa_v_bg * visc_factor)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OceanState:
    ext: State2D                     # 2D external state (eta, Qx, Qy)
    ux: jax.Array                    # (nl, 6, nt)
    uy: jax.Array
    T: jax.Array                     # (nl, 6, nt)
    S: jax.Array
    turb_k: jax.Array                # (nl, nt)
    turb_eps: jax.Array
    nu_t: jax.Array                  # (nl, nt)
    kappa_t: jax.Array
    time: jax.Array                  # scalar


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Forcing3D:
    forcing2d: Forcing2D = Forcing2D()
    tau_x: Optional[jax.Array] = None    # (3, nt) wind stress / rho0 [m^2/s^2]
    tau_y: Optional[jax.Array] = None
    T_open: Optional[jax.Array] = None   # (nl, 6, nt) open-boundary tracer
    S_open: Optional[jax.Array] = None


def init_state(geom: G.Geom2D, vg: VGrid, T0: float = 10.0, S0: float = 35.0,
               dtype=None) -> OceanState:
    if dtype is None:      # follow the ambient default (f64 under x64 tests)
        dtype = jnp.zeros(()).dtype
    nt = geom.nt
    nl = vg.nl
    z2 = jnp.zeros((3, nt), dtype)
    z3 = jnp.zeros((nl, 6, nt), dtype)
    ts = turbulence.init_turbulence(nl, nt, dtype)
    return OceanState(
        ext=State2D(z2, z2, z2), ux=z3, uy=z3,
        T=jnp.full((nl, 6, nt), T0, dtype), S=jnp.full((nl, 6, nt), S0, dtype),
        turb_k=ts.k, turb_eps=ts.eps, nu_t=ts.nu_t, kappa_t=ts.kappa_t,
        time=jnp.zeros((), dtype))


class StageOut(NamedTuple):
    ext: State2D
    ux: jax.Array
    uy: jax.Array
    T: jax.Array
    S: jax.Array
    turb: turbulence.TurbState
    r: jax.Array         # internal pressure gradient (diagnostics)
    w_tilde: jax.Array   # vertical velocity (diagnostics)


def _momentum_extra(geom, vge, cfg, r, ux_e, uy_e):
    """Coriolis - f ez x u and internal pressure -M r/rho0 (raw assembled)."""
    fx = cfg.coriolis_f * vertical.mass_apply3d(geom, vge.jz, uy_e) \
        - vertical.mass_apply3d(geom, vge.jz, r[0]) / RHO0
    fy = -cfg.coriolis_f * vertical.mass_apply3d(geom, vge.jz, ux_e) \
        - vertical.mass_apply3d(geom, vge.jz, r[1]) / RHO0
    return jnp.stack([fx, fy])


def _bottom_drag_coeff(cfg, ux_e, uy_e):
    """Linearised quadratic drag Cd |u_bot| at the floor nodes: (3, nt)."""
    ub = ux_e[-1, 3:6, :]
    vb = uy_e[-1, 3:6, :]
    return cfg.cd_bottom * jnp.sqrt(ub ** 2 + vb ** 2 + 1e-12)


def _wind_rhs(geom, tau, nl, nt, dtype):
    """Surface Neumann wind-stress contribution to the vertical-solve RHS."""
    out = jnp.zeros((nl, 6, nt), dtype)
    if tau is None:
        return out
    return out.at[0, 0:3, :].set(G.vol_scatter(geom, G.vol_interp(tau)))


def _pressure_dbar(vg: VGrid, vge) -> jax.Array:
    """Approximate pressure (dbar ~ m depth) at prism nodes for the EOS."""
    z = node_z(vg, vge)               # (nl, 6, nt)
    eta6 = jnp.concatenate([vge.eta, vge.eta], axis=-2)   # (6, nt)
    return jnp.maximum(eta6 - z, 0.0)


def stage(geom: G.Geom2D, vg: VGrid, cfg: OceanConfig, st0: OceanState,
          ux_e: jax.Array, uy_e: jax.Array, T_e: jax.Array, S_e: jax.Array,
          eta_e: jax.Array, turb0: turbulence.TurbState,
          dtau: float, m_sub: int, implicit: bool,
          forcing: Forcing3D,
          turb_base: Optional[turbulence.TurbState] = None,
          exchange2d=None, exchange_field=None) -> StageOut:
    """One IMEX stage: evaluate fluxes at (ux_e, ..., eta_e), advance the
    state *from st0* over dtau with m_sub external sub-steps.

    turb0 provides the mixing coefficients; turb_base (default turb0) is the
    state the turbulence model is advanced *from* (stage 2 restarts from t0
    like the rest of the state, while using midpoint coefficients)."""
    if turb_base is None:
        turb_base = turb0
    if exchange_field is not None:
        # distributed: refresh ghost rings of the evaluation fields (the
        # external state is refreshed inside run_external)
        ux_e = exchange_field(ux_e)
        uy_e = exchange_field(uy_e)
        T_e = exchange_field(T_e)
        S_e = exchange_field(S_e)
        eta_e = exchange_field(eta_e)
    nl, nt = cfg.nl, geom.nt
    vge0 = layer_geometry(vg, st0.ext.eta, cfg.h_min)   # M0 mesh
    vgee = layer_geometry(vg, eta_e, cfg.h_min)         # evaluation mesh

    # --- per-stage shared interpolations (fused horizontal pipeline) --------
    # One EdgeCache per stage: the jz / {Jz/H} / eta / H exterior gathers and
    # edge interpolations are computed HERE exactly once and shared by the
    # pressure gradient, both flux speeds, the continuity RHS and both
    # advdiff calls below (core/horizontal.py).
    with jax.named_scope("stage.edge_cache"):
        hc = (horizontal.stage_cache(geom, vgee, cfg.h_min)
              if cfg.fused_horizontal else None)

    # --- density, pressure gradient r (matrix-free solve) -------------------
    with jax.named_scope("stage.pressure_gradient"):
        rho = eos.rho_prime(S_e, T_e, _pressure_dbar(vg, vgee), cfg.eos_kind)
        F_r, r_s = dg3d.pressure_gradient_rhs(geom, vg, vgee, rho, cache=hc)
        r = kops.solve_r(geom, F_r, r_s, backend=cfg.backend)  # (2,nl,6,nt)

    # --- component 1: horizontal flux prediction (with q, not qbar) ---------
    with jax.named_scope("stage.flux_prediction"):
        q = dg3d.transport_from_velocity(vgee, ux_e, uy_e)
        if hc is not None:
            tc_pred = horizontal.transport_cache(
                geom, vgee, vg, hc, q[0], q[1], h_min=cfg.h_min)
            flux_pred = tc_pred.flux
        else:
            tc_pred = None
            flux_pred = dg3d.lateral_flux_speed(
                geom, vgee, vg, q[0], q[1], eta_e, vg.b, h_min=cfg.h_min)
        nu_h = dg3d.smagorinsky_nu(geom, ux_e, uy_e, cfg.cs_smag)
        u_pair = jnp.stack([ux_e, uy_e])
        if hc is not None:
            # FieldStates of the evaluation velocity + its diffusion term,
            # built ONCE: the prediction and the momentum-update advdiff
            # interpolate the same fields, and the diffusion is
            # flux-independent
            fs_u = dg3d.field_states(geom, u_pair, bc_reflect=True)
            diff_u = dg3d.horizontal_diffusion(geom, vgee, nl, u_pair, nu_h,
                                               cache=hc, fcache=fs_u)
            f3h_pred = dg3d.horizontal_advection(
                geom, vgee, nl, u_pair, q[0], q[1], flux_pred,
                tcache=tc_pred, fcache=fs_u, backend=cfg.backend) + diff_u
        else:
            fs_u = diff_u = None
            f3h_pred = dg3d.horizontal_advdiff(
                geom, vgee, nl, u_pair, q[0], q[1], flux_pred, nu_h,
                bc_reflect=True)
        f3h_pred = f3h_pred + _momentum_extra(geom, vgee, cfg, r, ux_e, uy_e)

        # F_3D->2D: vertical sum + wind + (predicted) bottom drag
        drag = _bottom_drag_coeff(cfg, ux_e, uy_e)
        dq = G.vol_interp(drag)
        ubq = G.vol_interp(ux_e[-1, 3:6, :])
        vbq = G.vol_interp(uy_e[-1, 3:6, :])
        f3d2d_x = vsum_dofs(f3h_pred[0]) - G.vol_scatter(geom, dq * ubq)
        f3d2d_y = vsum_dofs(f3h_pred[1]) - G.vol_scatter(geom, dq * vbq)
        if forcing.tau_x is not None:
            f3d2d_x = f3d2d_x + G.mass_apply(geom, forcing.tau_x)
            f3d2d_y = f3d2d_y + G.mass_apply(geom, forcing.tau_y)

    # --- component 2: external mode burst ------------------------------------
    with jax.named_scope("stage.external_burst"):
        ext = dg2d.run_external(geom, vg.b, st0.ext, dtau, m_sub,
                                forcing.forcing2d, f3d2d_x, f3d2d_y,
                                h_min=cfg.h_min, exchange_fn=exchange2d,
                                exchange_period=cfg.halo_exchange_period)
        eta1 = ext.state.eta
        vge1 = layer_geometry(vg, eta1, cfg.h_min)

    # --- component 3: turbulence ---------------------------------------------
    with jax.named_scope("stage.turbulence"):
        dz = jnp.maximum(vgee.H.mean(axis=0, keepdims=True),
                         cfg.h_min) / nl                         # (1, nt)
        if cfg.use_gls and implicit:
            m2, n2 = turbulence.shear_and_buoyancy(ux_e, uy_e, rho, dz)
            turb1 = turbulence.gls_step(turb_base, m2, n2, dz, dtau)
        else:
            turb1 = turb0
        turb_used = turb1 if implicit else turb0
        kv = turbulence.to_nodes(turb_used.nu_t) + cfg.nu_v_bg
        kap = turbulence.to_nodes(turb_used.kappa_t) + cfg.kappa_v_bg

    # --- consistent transport, vertical velocity, mesh velocity --------------
    with jax.named_scope("stage.w_solve"):
        qbar = dg3d.consistent_transport(vgee, ux_e, uy_e, ext.q_bar_x,
                                         ext.q_bar_y, nl)
        fb_kw = (dict(fbar_edge=ext.fbar_edge,
                      qbar2d=(ext.q_bar_x, ext.q_bar_y))
                 if cfg.exact_consistency else {})
        if hc is not None:
            tc = horizontal.transport_cache(
                geom, vgee, vg, hc, qbar[0], qbar[1],
                h_min=cfg.h_min, **fb_kw)
            flux_c = tc.flux
        else:
            tc = None
            flux_c = dg3d.lateral_flux_speed(
                geom, vgee, vg, qbar[0], qbar[1], eta_e, vg.b,
                h_min=cfg.h_min, **fb_kw)
        w_t = kops.solve_w(
            geom, dg3d.continuity_rhs(geom, vgee, nl, qbar[0], qbar[1],
                                      flux_c, tcache=tc),
            backend=cfg.backend)

        wm_i = mesh_velocity(vg, st0.ext.eta, eta1, dtau)    # (nl+1, 3, nt)
        wm_nodes = jnp.concatenate([wm_i[:-1], wm_i[1:]], axis=1)
        wrel = w_t - wm_nodes
        # interface advective speeds: value from BELOW each interface
        wface = w_t[:, 0:3, :] - wm_i[:-1]                   # (nl, 3, nt)
        wface = jnp.concatenate(
            [wface, jnp.zeros((1, 3, nt), wface.dtype)], axis=0)  # floor: 0

    # --- components 4+5 horizontal RHS: momentum + tracers ------------------
    with jax.named_scope("stage.horizontal_rhs"):
        kap_h = dg3d.okubo_kappa(geom, nl)
        tr_pair = jnp.stack([T_e, S_e])
        open_vals = None
        if forcing.T_open is not None:
            open_vals = jnp.stack([forcing.T_open, forcing.S_open])
        if hc is not None:
            # momentum + tracers share flux_c; velocity FieldStates and the
            # momentum diffusion are reused from the prediction call
            f3h, f3h_tr = horizontal.advdiff_momentum_tracers(
                geom, vgee, nl, u_pair, tr_pair, qbar[0], qbar[1], flux_c,
                nu_h, kap_h, fs_u=fs_u, diff_u=diff_u, open_tr=open_vals,
                cache=hc, tcache=tc, backend=cfg.backend)
        else:
            f3h = dg3d.horizontal_advdiff(
                geom, vgee, nl, u_pair, qbar[0], qbar[1], flux_c, nu_h,
                bc_reflect=True)
            f3h_tr = dg3d.horizontal_advdiff(
                geom, vgee, nl, tr_pair, qbar[0], qbar[1], flux_c, kap_h,
                bc_reflect=False, open_values=open_vals)

    # --- component 4: momentum update ----------------------------------------
    with jax.named_scope("stage.momentum_update"):
        f3h = f3h + _momentum_extra(geom, vgee, cfg, r, ux_e, uy_e)
        # hoisted: ONE mass-blocks assembly per stage, shared by the momentum
        # and tracer implicit solves
        M1b = vertical.mass_blocks(geom, vge1.jz, nl) if implicit else None

        H1 = jnp.maximum(eta1 + vg.b, cfg.h_min)
        f2d_term = jnp.stack([
            vertical.mass_apply3d(geom, vge1.jz,
                                  expand2d(ext.f2d_x / H1, nl)),
            vertical.mass_apply3d(geom, vge1.jz,
                                  expand2d(ext.f2d_y / H1, nl))])
        m0u = jnp.stack([vertical.mass_apply3d(geom, vge0.jz, st0.ux),
                         vertical.mass_apply3d(geom, vge0.jz, st0.uy)])
        wind = jnp.stack([
            _wind_rhs(geom, forcing.tau_x, nl, nt, f3h.dtype),
            _wind_rhs(geom, forcing.tau_y, nl, nt, f3h.dtype)])
        rhs_u = m0u + dtau * (f3h + f2d_term + wind)

        A_u = vertical.assemble_vertical_operator(
            geom, nl, vgee.jz, wrel, wface, kv, vgee.H, drag_coeff=drag)
        if implicit:
            # assemble (M - dt A) and solve both velocity components in one
            # cell-layout sweep: the lane axis is the cell column axis, so
            # the blocks go to the kernel as assembled — no SoA<->cell
            # round-trip
            sys = vertical.implicit_system(M1b, A_u, dtau)
            u1 = kops.block_thomas(sys, rhs_u, backend=cfg.backend)
        else:
            f3v = jnp.stack([vertical.blocks_matvec(A_u, ux_e),
                             vertical.blocks_matvec(A_u, uy_e)])
            u1 = jnp.stack([
                vertical.mass_solve3d(geom, vge1.jz,
                                      rhs_u[0] + dtau * f3v[0]),
                vertical.mass_solve3d(geom, vge1.jz,
                                      rhs_u[1] + dtau * f3v[1])])

    # --- component 5: tracers (T & S solved together) -------------------------
    with jax.named_scope("stage.tracer_update"):
        m0tr = jnp.stack([vertical.mass_apply3d(geom, vge0.jz, st0.T),
                          vertical.mass_apply3d(geom, vge0.jz, st0.S)])
        rhs_tr = m0tr + dtau * f3h_tr
        A_tr = vertical.assemble_vertical_operator(
            geom, nl, vgee.jz, wrel, wface, kap, vgee.H, drag_coeff=None)
        if implicit:
            sysT = vertical.implicit_system(M1b, A_tr, dtau)
            tr1 = kops.block_thomas(sysT, rhs_tr, backend=cfg.backend)
        else:
            f3v_tr = jnp.stack([vertical.blocks_matvec(A_tr, T_e),
                                vertical.blocks_matvec(A_tr, S_e)])
            tr1 = jnp.stack([
                vertical.mass_solve3d(geom, vge1.jz,
                                      rhs_tr[0] + dtau * f3v_tr[0]),
                vertical.mass_solve3d(geom, vge1.jz,
                                      rhs_tr[1] + dtau * f3v_tr[1])])

    if cfg.use_gls and not implicit:
        # explicit steps update turbulence last (paper Fig. 2a caption),
        # advancing from turb_base (t0) with end-of-step shear/buoyancy
        with jax.named_scope("stage.turbulence_final"):
            rho1 = eos.rho_prime(tr1[1], tr1[0], _pressure_dbar(vg, vge1),
                                 cfg.eos_kind)
            m2, n2 = turbulence.shear_and_buoyancy(u1[0], u1[1], rho1, dz)
            turb1 = turbulence.gls_step(turb_base, m2, n2, dz, dtau)

    return StageOut(ext=ext.state, ux=u1[0], uy=u1[1], T=tr1[0], S=tr1[1],
                    turb=turb1, r=r, w_tilde=w_t)


def state_to_cell(st: OceanState, backend: Optional[str] = None) -> dict:
    """Cell-layout (nc, nl*6, 128) copies of the 3D prognostic fields via the
    cell_transpose kernel — the step-boundary transform (paper §2.1.2) for
    cell-major storage/IO.  Inside a step everything already runs in lane
    (=cell column) layout, so this is the only SoA<->cell transpose."""
    f = lambda x: kops.soa_to_cell(x, backend=backend)
    return {"ux": f(st.ux), "uy": f(st.uy), "T": f(st.T), "S": f(st.S)}


def state_from_cell(st: OceanState, cells: dict, nt: int,
                    backend: Optional[str] = None) -> OceanState:
    """Rebuild the SoA prognostic fields from state_to_cell output."""
    f = lambda x: kops.cell_to_soa(x, nt, backend=backend)
    return dataclasses.replace(st, ux=f(cells["ux"]), uy=f(cells["uy"]),
                               T=f(cells["T"]), S=f(cells["S"]))


def step(geom: G.Geom2D, vg: VGrid, cfg: OceanConfig, st: OceanState,
         forcing: Forcing3D = Forcing3D(),
         exchange2d=None, exchange_field=None) -> OceanState:
    """One full internal step: IMEX midpoint (stage 1 implicit over dt/2,
    stage 2 explicit over dt with midpoint fluxes).  The exchange hooks are
    supplied by the distributed runtime (distributed/ocean.py)."""
    turb0 = turbulence.TurbState(st.turb_k, st.turb_eps, st.nu_t, st.kappa_t)

    with jax.named_scope("imex.stage1"):
        s1 = stage(geom, vg, cfg, st, st.ux, st.uy, st.T, st.S, st.ext.eta,
                   turb0, cfg.dt / 2, max(cfg.m_2d // 2, 1),
                   cfg.implicit_stage1, forcing,
                   exchange2d=exchange2d, exchange_field=exchange_field)

    with jax.named_scope("imex.stage2"):
        s2 = stage(geom, vg, cfg, st, s1.ux, s1.uy, s1.T, s1.S, s1.ext.eta,
                   s1.turb, cfg.dt, cfg.m_2d, False, forcing,
                   turb_base=turb0,
                   exchange2d=exchange2d, exchange_field=exchange_field)

    return OceanState(
        ext=s2.ext, ux=s2.ux, uy=s2.uy, T=s2.T, S=s2.S,
        turb_k=s2.turb.k, turb_eps=s2.turb.eps, nu_t=s2.turb.nu_t,
        kappa_t=s2.turb.kappa_t, time=st.time + cfg.dt)
