"""Vertical extrusion: prismatic columns over the 2D mesh (paper §1, Fig. 1b).

sigma-layer vertical grid (DESIGN.md §4): each column of prisms follows the
free surface with uniformly spaced layers, so the layer thickness is
dz = H/nl per horizontal node and the vertical Jacobian J_z = H/(2 nl) is a
P1-in-horizontal field, constant within a column in zeta.  This keeps the
paper's full machinery — time-varying mass matrices M0 != M1, mesh velocity
w_m, mesh-aligned IMEX splitting — while making the extrusion conformal.

3D DG fields: (nl, 6, nt); nodes 0..2 = top face, 3..5 = bottom face
(horizontal node order matches the 2D mesh). Layer 0 is the surface layer
(paper: "prisms within a column are ordered from top to bottom").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import geometry as G


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VGrid:
    """Static vertical grid description."""
    b: jax.Array                        # (3, nt) bathymetry at 2D nodes
    nl: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nt(self) -> int:
        return self.b.shape[-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VertGeom:
    """Time-dependent vertical geometry for a given free surface eta."""
    H: jax.Array        # (3, nt) column height
    jz: jax.Array       # (3, nt) vertical jacobian H/(2 nl), same for all layers
    eta: jax.Array      # (3, nt)


def layer_geometry(vg: VGrid, eta: jax.Array, h_min: float = 0.05) -> VertGeom:
    H = jnp.maximum(eta + vg.b, h_min)
    return VertGeom(H=H, jz=H / (2.0 * vg.nl), eta=eta)


def interface_z(vg: VGrid, vge: VertGeom) -> jax.Array:
    """(nl+1, 3, nt) interface elevations z_k = eta - H*k/nl, k=0..nl."""
    k = jnp.arange(vg.nl + 1, dtype=vge.H.dtype)[:, None, None]
    return vge.eta[None] - vge.H[None] * (k / vg.nl)


def mesh_velocity(vg: VGrid, eta0: jax.Array, eta1: jax.Array,
                  dt: float) -> jax.Array:
    """w_m at interfaces, (nl+1, 3, nt): d z_k/dt = eta_dot * (1 - k/nl).

    Linear in zeta within each layer -> the discrete GCL holds exactly
    (tracer-constancy test relies on this).
    """
    etad = (eta1 - eta0) / dt
    k = jnp.arange(vg.nl + 1, dtype=eta0.dtype)[:, None, None]
    return etad[None] * (1.0 - k / vg.nl)


# --- 3D node/field helpers ---------------------------------------------------
def expand2d(f2d: jax.Array, nl: int) -> jax.Array:
    """Broadcast a 2D nodal field (..., 3, nt) to a 3D field (..., nl, 6, nt)."""
    f6 = jnp.concatenate([f2d, f2d], axis=-2)          # (..., 6, nt)
    return jnp.broadcast_to(f6[..., None, :, :],
                            (*f6.shape[:-2], nl, 6, f6.shape[-1]))


def vsum_dofs(f3d: jax.Array) -> jax.Array:
    """Sum over vertical DOFs: (..., nl, 6, nt) -> (..., 3, nt).

    With q := J_z u projected to P1, this is the discrete vertical integral
    (paper eq. 18): sum_l (q_top + q_bot) at each horizontal node.
    """
    return f3d[..., :3, :].sum(axis=-3) + f3d[..., 3:, :].sum(axis=-3)


def node_z(vg: VGrid, vge: VertGeom) -> jax.Array:
    """z at the 6 nodes of each prism: (nl, 6, nt)."""
    zi = interface_z(vg, vge)      # (nl+1, 3, nt)
    return jnp.concatenate([zi[:-1], zi[1:]], axis=1)  # top nodes then bottom
