"""Unstructured 2D triangular meshes for the SLIM reproduction.

Build-time (numpy, static) mesh machinery:
  * synthetic unstructured triangulations (jittered structured grids, basins,
    channels, reef belts) — the paper's meshes (gmsh/GBR) are not
    redistributable, so benchmarks use synthetic meshes of matched size,
  * Hilbert-curve reordering of triangles (paper §2.1: cache locality of the
    SoA layout on an unstructured mesh),
  * DG connectivity: per-(triangle, edge) neighbour triangle / neighbour edge /
    orientation maps used by the flux gathers.

Conventions
-----------
Reference triangle: r0=(0,0), r1=(1,0), r2=(0,1); P1 basis
phi0 = 1-xi-eta, phi1 = xi, phi2 = eta.  Local edge e connects local nodes
(e, (e+1)%3); outward normals.  A consistently-oriented (CCW) mesh traverses a
shared edge in opposite directions from its two sides, which the connectivity
builder asserts.

DG field layouts (JAX side):
  2D field: (3, nt)            [node, triangle]  — triangle index minor (lanes)
  3D field: (nl, 6, nt)        [layer, node, triangle]
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

EDGE_NODES = np.array([[0, 1], [1, 2], [2, 0]])  # local nodes of local edge e

# edge types
INTERIOR, WALL, OPEN = 0, 1, 2


# ---------------------------------------------------------------------------
# Hilbert curve ordering (paper §2.1: reorder the 2D mesh along a Hilbert
# curve so that SoA neighbour accesses stay cache/VMEM-local).
# ---------------------------------------------------------------------------
def _hilbert_rot(n: int, x: np.ndarray, y: np.ndarray, rx: np.ndarray, ry: np.ndarray):
    """Rotate/flip quadrant (vectorised classic Hilbert rotation)."""
    mask = ry == 0
    flip = mask & (rx == 1)
    x = np.where(flip, n - 1 - x, x)
    y = np.where(flip, n - 1 - y, y)
    xs = np.where(mask, y, x)
    ys = np.where(mask, x, y)
    return xs, ys


def hilbert_index(px: np.ndarray, py: np.ndarray, order: int = 16) -> np.ndarray:
    """Hilbert index of points scaled to a 2**order x 2**order grid."""
    n = 1 << order
    def scale(p):
        lo, hi = p.min(), p.max()
        span = max(hi - lo, 1e-30)
        return np.minimum((n - 1), ((p - lo) / span * (n - 1)).astype(np.int64))
    x, y = scale(px), scale(py)
    d = np.zeros_like(x)
    s = n >> 1
    while s > 0:
        rx = ((x & s) > 0).astype(np.int64)
        ry = ((y & s) > 0).astype(np.int64)
        d += s * s * ((3 * rx) ^ ry)
        x, y = _hilbert_rot(s, x, y, rx, ry)
        s >>= 1
    return d


# ---------------------------------------------------------------------------
# Mesh container
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Mesh2D:
    """Static unstructured triangular mesh with DG connectivity."""

    xy: np.ndarray          # (nv, 2) vertex coordinates
    tri: np.ndarray         # (nt, 3) vertex indices, CCW
    neigh_tri: np.ndarray   # (nt, 3) neighbour triangle per local edge (self if boundary)
    neigh_edge: np.ndarray  # (nt, 3) local edge index in the neighbour
    edge_type: np.ndarray   # (nt, 3) INTERIOR / WALL / OPEN

    @property
    def nt(self) -> int:
        return self.tri.shape[0]

    @property
    def nv(self) -> int:
        return self.xy.shape[0]

    # -- geometry ----------------------------------------------------------
    def node_xy(self) -> np.ndarray:
        """(nt, 3, 2) coordinates of the 3 P1 nodes of each triangle."""
        return self.xy[self.tri]

    def areas(self) -> np.ndarray:
        p = self.node_xy()
        d1 = p[:, 1] - p[:, 0]
        d2 = p[:, 2] - p[:, 0]
        return 0.5 * (d1[:, 0] * d2[:, 1] - d1[:, 1] * d2[:, 0])

    def centroids(self) -> np.ndarray:
        return self.node_xy().mean(axis=1)

    # -- transforms ----------------------------------------------------------
    def reorder(self, perm: np.ndarray) -> "Mesh2D":
        """Permute triangles: new triangle i = old triangle perm[i]."""
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm))
        return Mesh2D(
            xy=self.xy,
            tri=self.tri[perm],
            neigh_tri=inv[self.neigh_tri[perm]],
            neigh_edge=self.neigh_edge[perm],
            edge_type=self.edge_type[perm],
        )

    def hilbert_reorder(self) -> "Mesh2D":
        c = self.centroids()
        perm = np.argsort(hilbert_index(c[:, 0], c[:, 1]), kind="stable")
        return self.reorder(perm)

    def validate(self) -> None:
        a = self.areas()
        assert (a > 0).all(), f"{(a <= 0).sum()} inverted/degenerate triangles"
        nt = self.nt
        assert self.neigh_tri.shape == (nt, 3)
        # interior edges must be mutual with opposite orientation
        for e in range(3):
            interior = self.edge_type[:, e] == INTERIOR
            t = np.arange(nt)[interior]
            n = self.neigh_tri[interior, e]
            ne = self.neigh_edge[interior, e]
            assert (self.neigh_tri[n, ne] == t).all(), "connectivity not mutual"
            a_, b_ = EDGE_NODES[e].T
            my_a = self.tri[t, EDGE_NODES[e][0]]
            my_b = self.tri[t, EDGE_NODES[e][1]]
            th_a = self.tri[n, EDGE_NODES[ne, 0]]
            th_b = self.tri[n, EDGE_NODES[ne, 1]]
            assert (my_a == th_b).all() and (my_b == th_a).all(), (
                "shared edge not traversed in opposite directions")


def build_connectivity(tri: np.ndarray, open_edge_fn: Optional[Callable] = None,
                       xy: Optional[np.ndarray] = None) -> Mesh2D:
    """Derive neighbour maps from a (nt,3) CCW triangle list.

    open_edge_fn(midpoints: (k,2)) -> bool mask marks boundary edges as OPEN
    instead of WALL.
    """
    nt = tri.shape[0]
    # undirected edge key -> (tri, local_edge)
    a = tri[:, EDGE_NODES[:, 0]]  # (nt,3)
    b = tri[:, EDGE_NODES[:, 1]]
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    key = lo.astype(np.int64) * (tri.max() + 1) + hi.astype(np.int64)
    flat = key.ravel()
    order = np.argsort(flat, kind="stable")
    sorted_keys = flat[order]
    neigh_tri = np.tile(np.arange(nt)[:, None], (1, 3))
    neigh_edge = np.tile(np.arange(3)[None, :], (nt, 1))
    edge_type = np.full((nt, 3), WALL, dtype=np.int64)

    # pairs of identical keys are the two sides of an interior edge
    same = sorted_keys[:-1] == sorted_keys[1:]
    i0 = order[:-1][same]
    i1 = order[1:][same]
    t0, e0 = i0 // 3, i0 % 3
    t1, e1 = i1 // 3, i1 % 3
    neigh_tri[t0, e0] = t1
    neigh_edge[t0, e0] = e1
    neigh_tri[t1, e1] = t0
    neigh_edge[t1, e1] = e0
    edge_type[t0, e0] = INTERIOR
    edge_type[t1, e1] = INTERIOR

    if open_edge_fn is not None and xy is not None:
        bnd = edge_type == WALL
        tb, eb = np.nonzero(bnd)
        mids = 0.5 * (xy[tri[tb, EDGE_NODES[eb, 0]]] + xy[tri[tb, EDGE_NODES[eb, 1]]])
        is_open = open_edge_fn(mids)
        edge_type[tb[is_open], eb[is_open]] = OPEN

    m = Mesh2D(xy=xy, tri=tri, neigh_tri=neigh_tri, neigh_edge=neigh_edge,
               edge_type=edge_type)
    return m


# ---------------------------------------------------------------------------
# Synthetic mesh factories
# ---------------------------------------------------------------------------
def rect_mesh(nx: int, ny: int, lx: float = 1.0, ly: float = 1.0,
              jitter: float = 0.0, seed: int = 0,
              open_edge_fn: Optional[Callable] = None,
              hilbert: bool = True) -> Mesh2D:
    """Jittered structured triangulation of [0,lx]x[0,ly]: 2*nx*ny triangles.

    jitter in [0, ~0.25] moves interior vertices by jitter*h to make the mesh
    genuinely unstructured (irregular angles/areas) while provably valid.
    """
    xs = np.linspace(0.0, lx, nx + 1)
    ys = np.linspace(0.0, ly, ny + 1)
    X, Y = np.meshgrid(xs, ys, indexing="ij")
    xy = np.stack([X.ravel(), Y.ravel()], axis=1)
    if jitter > 0:
        rng = np.random.default_rng(seed)
        hx, hy = lx / nx, ly / ny
        interior = ((X > 0) & (X < lx) & (Y > 0) & (Y < ly)).ravel()
        d = rng.uniform(-1, 1, size=xy.shape) * np.array([hx, hy]) * jitter
        xy = xy + d * interior[:, None]

    def vid(i, j):
        return i * (ny + 1) + j

    tris = []
    for i in range(nx):
        for j in range(ny):
            v00, v10 = vid(i, j), vid(i + 1, j)
            v01, v11 = vid(i, j + 1), vid(i + 1, j + 1)
            if (i + j) % 2 == 0:  # alternate diagonals (union-jack-ish)
                tris.append([v00, v10, v11])
                tris.append([v00, v11, v01])
            else:
                tris.append([v00, v10, v01])
                tris.append([v10, v11, v01])
    tri = np.array(tris, dtype=np.int64)
    m = build_connectivity(tri, open_edge_fn=open_edge_fn, xy=xy)
    m.validate()
    if hilbert:
        m = m.hilbert_reorder()
    return m


def channel_mesh(nx: int, ny: int, lx: float, ly: float, jitter: float = 0.15,
                 seed: int = 0, hilbert: bool = True) -> Mesh2D:
    """Channel with open boundaries at x=0 and x=lx (tidal forcing inlets)."""
    def open_fn(mids):
        return (mids[:, 0] < 1e-9 * lx + 1e-12) | (mids[:, 0] > lx * (1 - 1e-9))
    return rect_mesh(nx, ny, lx, ly, jitter, seed, open_edge_fn=open_fn,
                     hilbert=hilbert)


# ---------------------------------------------------------------------------
# Bathymetries (positive depth below reference level)
# ---------------------------------------------------------------------------
def flat_bathymetry(depth: float) -> Callable[[np.ndarray], np.ndarray]:
    return lambda p: np.full(p.shape[0], depth)


def shelf_bathymetry(h_shallow: float, h_deep: float, lx: float) -> Callable:
    """Linear shelf from shallow (x=0, 'coast') to deep (x=lx, 'open ocean')."""
    def f(p):
        s = np.clip(p[:, 0] / lx, 0, 1)
        return h_shallow + (h_deep - h_shallow) * s
    return f


def reef_bathymetry(h_shallow: float, h_deep: float, lx: float, ly: float,
                    n_reefs: int = 40, seed: int = 3) -> Callable:
    """Reef-belt bathymetry (GBR-like §5): shelf + gaussian reef bumps."""
    rng = np.random.default_rng(seed)
    cx = rng.uniform(0.15 * lx, 0.6 * lx, n_reefs)
    cy = rng.uniform(0.05 * ly, 0.95 * ly, n_reefs)
    rr = rng.uniform(0.01, 0.03, n_reefs) * min(lx, ly)
    def f(p):
        s = np.clip(p[:, 0] / lx, 0, 1)
        h = h_shallow + (h_deep - h_shallow) * s ** 2
        for k in range(n_reefs):
            d2 = (p[:, 0] - cx[k]) ** 2 + (p[:, 1] - cy[k]) ** 2
            h = h - (h - h_shallow * 0.3) * 0.8 * np.exp(-d2 / (2 * rr[k] ** 2))
        return np.maximum(h, 0.2 * h_shallow)
    return f
