"""Error-feedback int8 gradient compression for the data-parallel all-reduce
(beyond-paper distributed-optimization trick #3, DESIGN.md §5).

Wire format: per-leaf global scale (one f32 pmax) + int8 quantised gradient;
the all-reduce itself runs on int32-accumulated int8 payloads — 4x less ICI
traffic than f32 (2x vs bf16).  Quantisation error is kept in an error-
feedback accumulator (SGD-EF / 1-bit-Adam style), which restores full
convergence asymptotically.

Used by the shard_map DP training variant (`compressed_grad_psum` inside a
shard_map over the data axis); the GSPMD path keeps standard collectives.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_state(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_grad_psum(grads: Any, err: Any, axis_name: str,
                         n_devices: int) -> Tuple[Any, Any]:
    """All-reduce-mean gradients over `axis_name` with int8 + error feedback.

    Must run inside shard_map/pmap over the DP axis.  Returns
    (mean_grads, new_error_state)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale across the axis so int payloads are summable
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax / 127.0, 1e-30)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale       # error feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * scale / n_devices
        return mean.astype(g.dtype), new_e

    out = jax.tree_util.tree_map(one, grads, err)
    means = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree_util.tree_map(lambda t: t[1], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return means, errs
