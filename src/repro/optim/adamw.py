"""Sharded AdamW with global-norm clipping.

Pure pytree implementation (no optax dependency); moments inherit the
parameter shardings (plus optional ZeRO-1 resharding via
models/sharding.opt_pspecs). Master weights stay in the parameter dtype;
moments in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq))


def update(grads, state: AdamWState, params,
           cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-12))
    step = state.step + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m + (1 - cfg.b1) * g
        v1 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m1 / bc1
        vh = v1 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m1, v1

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(m=new_m, v=new_v, step=step)
