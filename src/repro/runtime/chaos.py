"""Deterministic fault injection (chaos harness) for simulation campaigns.

The paper's headline workload — GBR at 5x resolution, physical-to-numerical
time ratio 100 — means week-long campaigns on hundreds of GPUs where
*something is always failing*.  This module makes every failure class the
runtime claims to survive reproducible on a laptop: a seeded ``FaultPlan``
fires faults at named *sites* compiled into the production code, so a
recovery path is a test, not a hope.

Sites (each a ``chaos.site(...)`` marker; a no-op unless a plan is active):

  ``sim.state``                value hook on the state entering a step —
                               NaN/Inf poisoning of a chosen field at a
                               chosen step (detected downstream by the
                               ``obs.diagnostics`` non-finite localiser)
  ``runner.step``              event hook at the top of the runner loop —
                               simulated preemption (SIGTERM to self) and
                               straggler stalls (sleep)
  ``checkpoint.write``         event hook inside the async save worker —
                               raising here simulates a disk/quota failure
                               in the background thread
  ``checkpoint.saved``         event hook after a checkpoint directory has
                               landed — truncate a leaf ``.npy``, delete a
                               leaf, or rewrite the ``latest`` pointer
                               stale/dangling
  ``halo.payload``             value hook on each received halo buffer in
                               ``distributed/halo.py`` (fires at TRACE
                               time: the corruption is baked into the
                               compiled program, step gating does not apply)
  ``runner.restore_shardings`` value hook on the shardings used at restore —
                               swapping them simulates an elastic restore
                               onto a different device layout

Usage::

    plan = chaos.FaultPlan([chaos.Fault("sim.state", "poison_nan",
                                        step=5, field="T")], seed=0)
    with chaos.active(plan):
        runner.run(state, n_steps=8)
    assert plan.log[0]["kind"] == "poison_nan"

Determinism: a plan is a pure function of (seed, faults); poison positions
come from ``numpy.random.default_rng([seed, step])`` and every firing is
appended to ``plan.log`` and counted in the ``chaos.fired`` metrics counter.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import re
import signal
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..obs import metrics as obs_metrics

SITES = ("sim.state", "runner.step", "checkpoint.write", "checkpoint.saved",
         "halo.payload", "runner.restore_shardings")

KINDS = ("poison_nan", "poison_inf",          # sim.state
         "preempt", "stall",                  # runner.step
         "io_error",                          # checkpoint.write
         "truncate", "drop_leaf",             # checkpoint.saved
         "stale_latest", "dangling_latest",   # checkpoint.saved
         "halo_nan",                          # halo.payload
         "reshard")                           # runner.restore_shardings


@dataclasses.dataclass
class Fault:
    """One injectable failure: fire ``kind`` at ``site`` when the step
    matches, at most ``count`` times (count<=0: unlimited)."""
    site: str
    kind: str
    step: Optional[int] = None     # fire when ctx step == this (None: always)
    field: Optional[str] = None    # leaf-name selector (poison / drop_leaf /
                                   # truncate); None: seeded random leaf
    count: int = 1
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)
    fired: int = 0                 # mutable firing counter

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown chaos site {self.site!r}; "
                             f"sites: {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"kinds: {KINDS}")


def _leaf_segments(path) -> List[str]:
    """Identifier segments of a key path ('.ext.eta' -> ['ext', 'eta'])."""
    return re.findall(r"[A-Za-z0-9_]+", jax.tree_util.keystr(path))


class FaultPlan:
    """A seeded, ordered set of faults plus the log of what actually fired."""

    def __init__(self, faults, seed: int = 0):
        self.faults = list(faults)
        self.seed = int(seed)
        self.log: List[dict] = []
        self._lock = threading.Lock()   # sites fire from worker threads too

    # ------------------------------------------------------------------ fire
    def fire(self, site: str, value: Any, step: Optional[int] = None,
             **ctx) -> Any:
        for f in self.faults:
            if f.site != site:
                continue
            if f.step is not None and step != f.step:
                continue
            with self._lock:
                if f.count > 0 and f.fired >= f.count:
                    continue
                f.fired += 1
            value = self._inject(f, value, step, ctx)
        return value

    def _record(self, f: Fault, step, detail: str) -> None:
        with self._lock:
            self.log.append(dict(site=f.site, kind=f.kind, step=step,
                                 detail=detail))
        obs_metrics.default().counter("chaos.fired", site=f.site,
                                      kind=f.kind).inc()

    # -------------------------------------------------------------- injectors
    def _inject(self, f: Fault, value, step, ctx):
        if f.kind in ("poison_nan", "poison_inf"):
            return self._poison(f, value, step)
        if f.kind == "preempt":
            self._record(f, step, "SIGTERM to self")
            os.kill(os.getpid(), signal.SIGTERM)
            return value
        if f.kind == "stall":
            secs = float(f.args.get("seconds", 0.5))
            self._record(f, step, f"stall {secs}s")
            time.sleep(secs)
            return value
        if f.kind == "io_error":
            self._record(f, step, "injected write failure")
            raise OSError("chaos: injected checkpoint write failure")
        if f.kind in ("truncate", "drop_leaf"):
            return self._corrupt_leaf(f, value, step, ctx)
        if f.kind in ("stale_latest", "dangling_latest"):
            return self._corrupt_latest(f, value, step, ctx)
        if f.kind == "halo_nan":
            self._record(f, step, f"halo payload -> NaN "
                                  f"(offset={ctx.get('offset')})")
            return jax.numpy.full_like(value, jax.numpy.nan)
        if f.kind == "reshard":
            self._record(f, step, "restore shardings swapped")
            return f.args.get("shardings", value)
        raise AssertionError(f.kind)   # unreachable: validated in Fault

    def _poison(self, f: Fault, tree, step):
        """Set one seeded element of one state leaf to NaN/Inf."""
        bad = np.nan if f.kind == "poison_nan" else np.inf
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        cands = [i for i, (p, leaf) in enumerate(leaves)
                 if hasattr(leaf, "shape") and np.size(leaf)
                 and np.issubdtype(np.asarray(leaf).dtype, np.floating)
                 and (f.field is None or
                      (_leaf_segments(p) and _leaf_segments(p)[-1] == f.field))]
        if not cands:
            raise ValueError(f"chaos poison: no leaf matches "
                             f"field={f.field!r}")
        rng = np.random.default_rng([self.seed, 0 if step is None else step])
        li = cands[int(rng.integers(len(cands)))]
        path, leaf = leaves[li]
        idx = int(rng.integers(np.size(leaf)))
        flat = [v for _, v in leaves]
        flat[li] = jax.numpy.asarray(leaf).reshape(-1).at[idx].set(
            bad).reshape(leaf.shape)
        self._record(f, step,
                     f"{jax.tree_util.keystr(path)}[{idx}] <- {bad}")
        return jax.tree_util.tree_unflatten(treedef, flat)

    def _corrupt_leaf(self, f: Fault, value, step, ctx):
        """Truncate or delete one leaf .npy of the just-written step dir."""
        d = ctx.get("path")
        if not d or not os.path.isdir(d):
            return value
        names = sorted(n for n in os.listdir(d) if n.endswith(".npy"))
        if f.field is not None:
            names = [n for n in names if f.field in n]
        if not names:
            return value
        rng = np.random.default_rng([self.seed, 0 if step is None else step])
        target = os.path.join(d, names[int(rng.integers(len(names)))])
        if f.kind == "drop_leaf":
            os.remove(target)
            self._record(f, step, f"removed {os.path.basename(target)}")
        else:
            size = os.path.getsize(target)
            with open(target, "r+b") as fh:
                fh.truncate(max(size // 2, 1))
            self._record(f, step, f"truncated {os.path.basename(target)} "
                                  f"{size}->{max(size // 2, 1)}B")
        return value

    def _corrupt_latest(self, f: Fault, value, step, ctx):
        root = ctx.get("directory")
        if not root or not os.path.isdir(root):
            return value
        if f.kind == "dangling_latest":
            name = "step_999999999"
        else:   # stale: point at the OLDEST surviving step (or dangle)
            steps = sorted(n for n in os.listdir(root)
                           if n.startswith("step_"))
            name = steps[0] if steps else "step_999999999"
        with open(os.path.join(root, "latest"), "w") as fh:
            fh.write(name)
        self._record(f, step, f"latest -> {name}")
        return value


# ---------------------------------------------------------------------------
# the active plan + the site marker compiled into production code
# ---------------------------------------------------------------------------
_active: Optional[FaultPlan] = None


@contextlib.contextmanager
def active(plan: FaultPlan):
    """Arm ``plan`` for the enclosed block (global, so the checkpoint worker
    thread and jit tracing both see it)."""
    global _active
    prev = _active
    _active = plan
    try:
        yield plan
    finally:
        _active = prev


def site(name: str, value: Any = None, step: Optional[int] = None,
         **ctx) -> Any:
    """Chaos site marker: identity unless a plan is active.

    Value sites return the (possibly corrupted) value; event sites are
    called for their side effects and return ``value`` unchanged."""
    plan = _active
    if plan is None:
        return value
    return plan.fire(name, value, step=step, **ctx)


# ---------------------------------------------------------------------------
# plan parsing (launch CLIs, chaos smoke): "kind@site[:k=v,...]"
# ---------------------------------------------------------------------------
def parse_fault(spec: str) -> Fault:
    """Parse ``kind@site[:key=value,...]`` — e.g.
    ``poison_nan@sim.state:step=5,field=T`` or
    ``truncate@checkpoint.saved:step=4``."""
    head, _, tail = spec.partition(":")
    kind, _, site_name = head.partition("@")
    kw: Dict[str, Any] = {}
    args: Dict[str, Any] = {}
    for item in filter(None, tail.split(",")):
        k, _, v = item.partition("=")
        if k in ("step", "count"):
            kw[k] = int(v)
        elif k == "field":
            kw[k] = v
        else:
            args[k] = float(v) if re.fullmatch(r"-?\d+(\.\d+)?", v) else v
    return Fault(site=site_name, kind=kind, args=args, **kw)


def plan_from_specs(specs, seed: int = 0) -> FaultPlan:
    return FaultPlan([parse_fault(s) for s in specs], seed=seed)
