"""Fault-tolerant training runtime.

Designed for 1000+ node fleets where *something is always failing*:
  * periodic async checkpoints + exact resume (data iterator state is the
    step counter, so restart is bitwise-deterministic),
  * preemption handling: SIGTERM/SIGINT triggers a final blocking checkpoint
    before exit (maintenance events on cloud TPUs),
  * crash recovery: a failing step (device error, NaN loss if configured)
    restores the last checkpoint and continues; repeated failures back off
    and eventually re-raise,
  * straggler detection: per-step wall-time EMA; steps slower than
    `straggler_factor` x EMA are counted and surfaced through `stats` —
    on a real fleet this feeds the scheduler's replace-node decision
    (JAX's SPMD model gives no in-band per-host mitigation, so detection +
    external replacement + elastic restore IS the mitigation path; the
    elastic checkpoint format restores onto any device count).

Observability (obs/): step wall time, the straggler EMA, and retry /
straggler counters stream into the default metrics registry; a step whose
metrics carry a physics ``diagnostics`` entry (the obs.diagnostics pytree or
its dict form) with the non-finite flag set is treated exactly like a NaN
loss — restore-and-retry — with the offending field/cell in the error.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..checkpoint.checkpoint import Checkpointer
from ..obs import metrics as obs_metrics


@dataclasses.dataclass
class RunnerConfig:
    checkpoint_dir: str = "checkpoints"
    checkpoint_every: int = 100
    keep_last: int = 3
    max_retries: int = 3
    straggler_factor: float = 2.0
    nan_is_failure: bool = True
    emit_metrics: bool = True      # stream runner stats to obs.metrics


def _diag_nonfinite(diag: Any) -> Optional[str]:
    """Non-finite reason string from a diagnostics entry, or None.

    Accepts the obs.diagnostics.Diagnostics pytree or its to_dict() form;
    anything without a ``nonfinite`` signal is ignored."""
    if diag is None:
        return None
    if isinstance(diag, dict):
        flag, field, cell = (diag.get("nonfinite"),
                             diag.get("bad_field_name", diag.get("bad_field")),
                             diag.get("bad_cell"))
    else:
        flag = getattr(diag, "nonfinite", None)
        field = getattr(diag, "bad_field", None)
        cell = getattr(diag, "bad_cell", None)
        if flag is not None:
            try:
                from ..obs.diagnostics import FIELDS
                fi = int(field)
                field = FIELDS[fi] if 0 <= fi < len(FIELDS) else fi
                cell = int(cell)
            except Exception:
                pass
    if flag is None or not bool(flag):
        return None
    return f"non-finite state (field={field}, cell={cell})"


class TrainRunner:
    """Drives step_fn(state, batch) -> (state, metrics) with FT wrapping."""

    def __init__(self, step_fn: Callable, dataset, cfg: RunnerConfig,
                 state_shardings: Any = None):
        self.step_fn = step_fn
        self.dataset = dataset
        self.cfg = cfg
        self.ckpt = Checkpointer(cfg.checkpoint_dir, cfg.keep_last)
        self.state_shardings = state_shardings
        self.stats = {"steps": 0, "retries": 0, "stragglers": 0,
                      "step_time_ema": None, "preempted": False}
        self._preempt = False

    def _install_signals(self):
        def handler(signum, frame):
            self._preempt = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def run(self, state: Any, n_steps: int, start_step: int = 0,
            resume: bool = True) -> Any:
        self._install_signals()
        step = start_step
        if resume:
            latest = self.ckpt.latest_step()
            if latest is not None and latest > step:
                state = self.ckpt.restore(state, latest,
                                          self.state_shardings)
                step = latest
        retries = 0
        while step < n_steps and not self._preempt:
            batch = self.dataset.batch_at(step)
            t0 = time.time()
            try:
                new_state, metrics = self.step_fn(state, batch)
                loss = metrics.get("loss") if isinstance(metrics, dict) \
                    else metrics
                if self.cfg.nan_is_failure and loss is not None and \
                        not np.isfinite(float(loss)):
                    raise FloatingPointError(f"non-finite loss at {step}")
                if self.cfg.nan_is_failure and isinstance(metrics, dict):
                    reason = _diag_nonfinite(metrics.get("diagnostics"))
                    if reason is not None:
                        raise FloatingPointError(f"{reason} at {step}")
            except Exception:
                retries += 1
                self.stats["retries"] += 1
                if self.cfg.emit_metrics:
                    obs_metrics.default().counter("runner.retries").inc()
                if retries > self.cfg.max_retries:
                    self.ckpt.wait()
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state = self.ckpt.restore(state, latest,
                                              self.state_shardings)
                    step = latest
                time.sleep(0.1 * 2 ** retries)   # backoff
                continue
            retries = 0
            state = new_state
            dt = time.time() - t0
            ema = self.stats["step_time_ema"]
            if ema is not None and dt > self.cfg.straggler_factor * ema:
                self.stats["stragglers"] += 1
                if self.cfg.emit_metrics:
                    obs_metrics.default().counter("runner.stragglers").inc()
            self.stats["step_time_ema"] = dt if ema is None else \
                0.9 * ema + 0.1 * dt
            if self.cfg.emit_metrics:
                reg = obs_metrics.default()
                reg.histogram("runner.step_time_s").observe(dt)
                reg.gauge("runner.step_time_ema_s").set(
                    self.stats["step_time_ema"])
            step += 1
            self.stats["steps"] += 1
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, state)
        if self._preempt:
            self.stats["preempted"] = True
            self.ckpt.save(step, state, blocking=True)
        self.ckpt.wait()
        return state
