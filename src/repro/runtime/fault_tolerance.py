"""Fault-tolerant runtime: a shared runner core, the training runner, and a
simulation runner with physics-aware recovery.

Designed for 1000+ node fleets where *something is always failing*:
  * periodic async checkpoints + exact resume (data iterator state is the
    step counter, so restart is bitwise-deterministic),
  * preemption handling: SIGTERM/SIGINT triggers a final blocking checkpoint
    before exit (maintenance events on cloud TPUs); the previous handlers
    are restored when ``run`` returns,
  * crash recovery: a failing step (device error, NaN loss/state) restores
    the newest INTACT checkpoint and continues; before the first checkpoint
    exists, recovery re-initialises from the caller's start snapshot (a
    "cold restore") instead of retrying a possibly-inconsistent in-memory
    state; repeated failures back off and eventually re-raise,
  * checkpoint-save failures (which surface from ``Checkpointer.wait`` as
    ``CheckpointError``) are retried once synchronously — a run never
    silently loses its checkpoint cadence,
  * straggler detection: per-step wall-time EMA; steps slower than
    `straggler_factor` x EMA are counted and surfaced through `stats`.

``TrainRunner`` drives ``step_fn(state, batch)`` (loss-shaped).
``SimulationRunner`` drives ``step_fn(state) -> (state, Diagnostics)``
(simulation-shaped) and replaces blind restore-and-retry with a
**graceful-degradation ladder**: a deterministic failure (the CFL blow-up
that dominates operational shallow-water runs) would otherwise restore the
same state, re-run the same step and fail identically until retries are
exhausted.  Instead, each consecutive retry climbs a rung — restore, then
restore + halve dt (``dt_2d = dt/m_2d`` halves consistently), then halve
again and optionally bump vertical viscosity — and once the CFL diagnostic
stays calm for ``recover_steps`` steps the runner re-widens one rung.
Every transition is emitted through ``obs.metrics``.

Chaos sites (``runtime/chaos.py``): ``runner.step`` (preemption/stall),
``sim.state`` (NaN/Inf poisoning of the state entering a step) and
``runner.restore_shardings`` (elastic restore onto different shardings).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from ..checkpoint.checkpoint import CheckpointError, Checkpointer
from ..obs import metrics as obs_metrics
from . import chaos


@dataclasses.dataclass
class RunnerConfig:
    checkpoint_dir: str = "checkpoints"
    checkpoint_every: int = 100
    keep_last: int = 3
    max_retries: int = 3
    straggler_factor: float = 2.0
    nan_is_failure: bool = True
    emit_metrics: bool = True      # stream runner stats to obs.metrics
    backoff_base_s: float = 0.1    # retry backoff: base * 2**retries


@dataclasses.dataclass
class LadderConfig:
    """Graceful-degradation ladder for the simulation runner.

    Rung r runs at ``dt * dt_factor**r`` (and, because ``m_2d`` is kept,
    ``dt_2d`` scales identically) with vertical viscosity bumped by
    ``visc_factor**r``.  ``max_rungs=0`` degenerates to blind
    restore-and-retry (the old behaviour)."""
    dt_factor: float = 0.5
    max_rungs: int = 2
    visc_factor: float = 1.0       # >1: multiply nu_v_bg/kappa_v_bg per rung
    recover_steps: int = 8         # consecutive calm steps before re-widening
    cfl_ok: float = 0.8            # re-widen when projected CFL at the wider
                                   # rung stays below cfl_ok * cfl_limit
    cfl_limit: float = 1.0         # absolute CFL ceiling for the projection


def _diag_nonfinite(diag: Any) -> Optional[str]:
    """Non-finite reason string from a diagnostics entry, or None.

    Accepts the obs.diagnostics.Diagnostics pytree or its to_dict() form;
    anything without a ``nonfinite`` signal is ignored."""
    if diag is None:
        return None
    if isinstance(diag, dict):
        flag, field, cell = (diag.get("nonfinite"),
                             diag.get("bad_field_name", diag.get("bad_field")),
                             diag.get("bad_cell"))
    else:
        flag = getattr(diag, "nonfinite", None)
        field = getattr(diag, "bad_field", None)
        cell = getattr(diag, "bad_cell", None)
        if flag is not None:
            try:
                from ..obs.diagnostics import FIELDS
                fi = int(field)
                field = FIELDS[fi] if 0 <= fi < len(FIELDS) else fi
                cell = int(cell)
            except Exception:
                pass
    if flag is None or not bool(flag):
        return None
    return f"non-finite state (field={field}, cell={cell})"


def _diag_value(diag: Any, key: str) -> Optional[float]:
    """Float diagnostic by name from a Diagnostics pytree or dict."""
    if diag is None:
        return None
    v = diag.get(key) if isinstance(diag, dict) else getattr(diag, key, None)
    if v is None:
        return None
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


class _RunnerBase:
    """Shared fault-tolerance core: checkpointer, signal handling, recovery,
    straggler accounting, metrics."""

    def __init__(self, cfg: RunnerConfig, state_shardings: Any = None):
        self.cfg = cfg
        self.ckpt = Checkpointer(cfg.checkpoint_dir, cfg.keep_last)
        self.state_shardings = state_shardings
        self.stats: Dict[str, Any] = {
            "steps": 0, "retries": 0, "stragglers": 0, "cold_restores": 0,
            "ckpt_failures": 0, "step_time_ema": None, "preempted": False}
        self._preempt = False
        self._prev_handlers: Optional[dict] = None

    # ----------------------------------------------------------- signals
    def _install_signals(self):
        def handler(signum, frame):
            self._preempt = True
        self._prev_handlers = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev_handlers[sig] = signal.getsignal(sig)
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread (tests)

    def _restore_signals(self):
        """Put back whatever handlers were installed before ``run`` — the
        runner's handler must not leak into subsequent code or pytest."""
        for sig, prev in (self._prev_handlers or {}).items():
            try:
                signal.signal(sig, prev)
            except (ValueError, TypeError):
                pass
        self._prev_handlers = None

    # ----------------------------------------------------------- metrics
    def _reg(self):
        return obs_metrics.default() if self.cfg.emit_metrics else None

    def _count(self, name: str, **labels):
        reg = self._reg()
        if reg is not None:
            reg.counter(name, **labels).inc()

    def _observe_step_time(self, dt: float):
        ema = self.stats["step_time_ema"]
        if ema is not None and dt > self.cfg.straggler_factor * ema:
            self.stats["stragglers"] += 1
            self._count("runner.stragglers")
        self.stats["step_time_ema"] = dt if ema is None else \
            0.9 * ema + 0.1 * dt
        reg = self._reg()
        if reg is not None:
            reg.histogram("runner.step_time_s").observe(dt)
            reg.gauge("runner.step_time_ema_s").set(
                self.stats["step_time_ema"])

    # -------------------------------------------------------- checkpoints
    def _save(self, step: int, state: Any, blocking: bool = False):
        """Checkpoint with one synchronous retry on failure — an async save
        error (surfaced here from the worker via ``wait``) costs one retry,
        never a silent gap in the checkpoint cadence."""
        try:
            self.ckpt.save(step, state, blocking=blocking)
        except CheckpointError:
            self.stats["ckpt_failures"] += 1
            self._count("runner.ckpt_failures")
            self.ckpt.save(step, state, blocking=True)

    def _drain(self):
        """Final wait; a pending async-save failure is counted, not raised
        over a (possibly) more interesting primary exception."""
        try:
            self.ckpt.wait()
        except CheckpointError:
            self.stats["ckpt_failures"] += 1
            self._count("runner.ckpt_failures")

    def _recover(self, template: Any, start_state: Any,
                 start_step: int) -> Tuple[Any, int]:
        """Newest intact checkpoint, or the caller's start snapshot (cold
        restore) when nothing on disk is restorable yet."""
        shardings = chaos.site("runner.restore_shardings",
                               self.state_shardings)
        state, step = self.ckpt.restore_latest(template, shardings)
        if state is None:
            self.stats["cold_restores"] += 1
            self._count("runner.cold_restores")
            return start_state, start_step
        return state, step


class TrainRunner(_RunnerBase):
    """Drives step_fn(state, batch) -> (state, metrics) with FT wrapping."""

    def __init__(self, step_fn: Callable, dataset, cfg: RunnerConfig,
                 state_shardings: Any = None):
        super().__init__(cfg, state_shardings)
        self.step_fn = step_fn
        self.dataset = dataset

    def run(self, state: Any, n_steps: int, start_step: int = 0,
            resume: bool = True) -> Any:
        self._install_signals()
        start_state, start0 = state, start_step   # cold-restore snapshot
        try:
            return self._run(state, n_steps, start_step, resume,
                             start_state, start0)
        finally:
            self._restore_signals()

    def _run(self, state, n_steps, start_step, resume, start_state, start0):
        step = start_step
        if resume:
            latest = self.ckpt.latest_step()
            if latest is not None and latest > step:
                state = self.ckpt.restore(state, latest,
                                          self.state_shardings)
                step = latest
        retries = 0
        while step < n_steps and not self._preempt:
            chaos.site("runner.step", step=step)
            if self._preempt:
                break
            batch = self.dataset.batch_at(step)
            t0 = time.time()
            try:
                new_state, metrics = self.step_fn(state, batch)
                loss = metrics.get("loss") if isinstance(metrics, dict) \
                    else metrics
                if self.cfg.nan_is_failure and loss is not None and \
                        not np.isfinite(float(loss)):
                    raise FloatingPointError(f"non-finite loss at {step}")
                if self.cfg.nan_is_failure and isinstance(metrics, dict):
                    reason = _diag_nonfinite(metrics.get("diagnostics"))
                    if reason is not None:
                        raise FloatingPointError(f"{reason} at {step}")
            except Exception:
                retries += 1
                self.stats["retries"] += 1
                self._count("runner.retries")
                if retries > self.cfg.max_retries:
                    self._drain()
                    raise
                state, step = self._recover(state, start_state, start0)
                time.sleep(self.cfg.backoff_base_s * 2 ** retries)
                continue
            retries = 0
            state = new_state
            self._observe_step_time(time.time() - t0)
            step += 1
            self.stats["steps"] += 1
            if step % self.cfg.checkpoint_every == 0:
                self._save(step, state)
        if self._preempt:
            self.stats["preempted"] = True
            self._save(step, state, blocking=True)
        self._drain()
        return state


class SimulationRunner(_RunnerBase):
    """Drives a compiled simulation step with physics-aware recovery.

    ``step_factory(model_cfg)`` must return a callable
    ``step_fn(state) -> (state, diagnostics)`` (the
    ``obs.diagnostics.step_with_diagnostics`` shape); the runner builds one
    per ladder rung so a dt change is a recompile, not a new runner.  The
    optional ``MonitorPolicy`` (``on_violation="halt"``) turns physics
    verdicts into step failures; without one, only the non-finite flag of
    the diagnostics is checked.

    Recovery ladder: consecutive retry r restores the newest intact
    checkpoint (or cold-restores from the caller's start snapshot) and runs
    at rung ``min(r-1, max_rungs)``.  Re-widening: while degraded, a step
    whose CFL — projected onto the next-wider rung — stays below
    ``cfl_ok * cfl_limit`` counts as calm; ``recover_steps`` consecutive
    calm steps step the ladder back up one rung."""

    def __init__(self, step_factory: Callable[[Any], Callable],
                 model_cfg: Any, cfg: RunnerConfig,
                 policy: Any = None, ladder: Optional[LadderConfig] = None,
                 state_shardings: Any = None):
        super().__init__(cfg, state_shardings)
        self.step_factory = step_factory
        self.model_cfg = model_cfg
        self.policy = policy
        self.ladder = ladder or LadderConfig()
        self.rung = 0
        self._step_fns: Dict[int, Callable] = {}
        self.stats.update({"ladder_engagements": 0, "ladder_transitions": 0})

    # ------------------------------------------------------------- ladder
    def _cfg_for_rung(self, rung: int) -> Any:
        if rung == 0:
            return self.model_cfg
        dt_f = self.ladder.dt_factor ** rung
        visc_f = self.ladder.visc_factor ** rung
        if hasattr(self.model_cfg, "with_recovery"):
            return self.model_cfg.with_recovery(dt_factor=dt_f,
                                                visc_factor=visc_f)
        return dataclasses.replace(self.model_cfg,
                                   dt=self.model_cfg.dt * dt_f)

    def _step_fn(self) -> Callable:
        if self.rung not in self._step_fns:
            self._step_fns[self.rung] = self.step_factory(
                self._cfg_for_rung(self.rung))
        return self._step_fns[self.rung]

    def _transition(self, rung: int, step: int, reason: str):
        if rung == self.rung:
            return
        prev, self.rung = self.rung, rung
        self.stats["ladder_transitions"] += 1
        if rung > prev:
            self.stats["ladder_engagements"] += 1
        reg = self._reg()
        if reg is not None:
            reg.counter("sim.ladder.transitions",
                        direction="down" if rung > prev else "up").inc()
            reg.gauge("sim.ladder.rung").set(rung)
            reg.event("sim.ladder.transition",
                      {"from": prev, "to": rung, "reason": reason,
                       "dt": float(getattr(self._cfg_for_rung(rung), "dt",
                                           0.0))}, step=step)

    def _calm(self, diag: Any) -> bool:
        """Would this step's CFL be acceptable one rung wider?"""
        cfl = _diag_value(diag, "cfl_2d")
        if cfl is None or not np.isfinite(cfl):
            return False
        projected = cfl / self.ladder.dt_factor    # dt one rung wider
        return projected < self.ladder.cfl_ok * self.ladder.cfl_limit

    # ---------------------------------------------------------------- run
    def run(self, state: Any, n_steps: int, start_step: int = 0,
            resume: bool = True) -> Any:
        self._install_signals()
        start_state, start0 = state, start_step
        try:
            return self._run(state, n_steps, start_step, resume,
                             start_state, start0)
        finally:
            self._restore_signals()

    def _run(self, state, n_steps, start_step, resume, start_state, start0):
        reg = self._reg()
        step = start_step
        if resume:
            latest = self.ckpt.latest_step()
            if latest is not None and latest > step:
                state = self.ckpt.restore(state, latest,
                                          self.state_shardings)
                step = latest
        retries = 0
        calm = 0
        while step < n_steps and not self._preempt:
            chaos.site("runner.step", step=step)
            if self._preempt:
                break
            t0 = time.time()
            try:
                st_in = chaos.site("sim.state", state, step=step)
                new_state, diag = self._step_fn()(st_in)
                if self.policy is not None:
                    self.policy.check(diag, step=step, registry=reg)
                reason = _diag_nonfinite(diag)
                if self.cfg.nan_is_failure and reason is not None:
                    raise FloatingPointError(f"{reason} at {step}")
            except Exception as e:
                retries += 1
                self.stats["retries"] += 1
                self._count("runner.retries")
                if retries > self.cfg.max_retries:
                    self._drain()
                    raise
                if reg is not None:
                    reg.event("sim.recovery", {"step": step, "retry": retries,
                                               "error": repr(e)}, step=step)
                state, step = self._recover(state, start_state, start0)
                self._transition(min(retries - 1, self.ladder.max_rungs),
                                 step, reason=repr(e))
                calm = 0
                time.sleep(self.cfg.backoff_base_s * 2 ** retries)
                continue
            retries = 0
            state = new_state
            self._observe_step_time(time.time() - t0)
            if self.rung > 0:
                calm = calm + 1 if self._calm(diag) else 0
                if calm >= self.ladder.recover_steps:
                    self._transition(self.rung - 1, step, reason="recovered")
                    calm = 0
            step += 1
            self.stats["steps"] += 1
            if step % self.cfg.checkpoint_every == 0:
                self._save(step, state)
        if self._preempt:
            self.stats["preempted"] = True
            self._save(step, state, blocking=True)
        self._drain()
        return state
