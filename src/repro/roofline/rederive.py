"""Recompute the `roofline` section of existing dry-run JSONs after model
changes (no recompilation needed — raw HLO stats are stored)."""
import json
import os
import sys

from . import analysis


def rederive(path: str):
    with open(path) as f:
        rec = json.load(f)
    st = analysis.HloStats(
        flops=rec["hlo"]["flops"], bytes=rec["hlo"]["bytes"],
        coll_bytes=rec["hlo"]["coll_bytes"],
        coll_by_kind=rec["hlo"].get("coll_by_kind", {}),
        n_collectives=rec["hlo"].get("n_collectives", 0))
    roof = analysis.roofline_from_stats(
        st, rec["chips"], rec.get("model_flops", 0.0),
        cost_analysis_flops=rec.get("cost_analysis", {}).get("flops", 0.0))
    rec["roofline"] = roof.to_dict()
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main(root="experiments/dryrun"):
    n = 0
    for dirpath, _, files in os.walk(root):
        for fn in files:
            if fn.endswith(".json"):
                rederive(os.path.join(dirpath, fn))
                n += 1
    print(f"rederived {n} records")


if __name__ == "__main__":
    main(*sys.argv[1:])
