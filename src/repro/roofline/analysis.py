"""Roofline analysis from compiled (SPMD-partitioned) HLO.

This container is CPU-only: the roofline terms are *derived from the compiled
artifact*, not measured.  Sources and models (EXPERIMENTS.md §Roofline):

  * FLOPs: parsed from `dot`/`convolution` ops in the post-partitioning HLO
    (2 * prod(output shape) * prod(contracted dims)), with while-loop bodies
    expanded by their trip counts — `compiled.cost_analysis()` counts loop
    bodies ONCE (verified empirically), so scanned layer stacks would be
    undercounted by ~n_layers without this.  Operand shapes are resolved
    through a per-computation symbol table (optimized HLO does not annotate
    operand types inline).
  * bytes: per top-level op (fusion boundaries = memory traffic): sum of
    operand + result buffer sizes, loop-expanded.  Post-fusion HLO makes this
    a reasonable HBM-traffic model (intra-fusion temporaries stay in
    registers/VMEM).
  * collective bytes (NOT in cost_analysis): per collective op, the wire
    bytes per participating device: all-reduce 2x (ring RS+AG), all-gather /
    reduce-scatter / all-to-all / collective-permute 1x buffer size.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute    = FLOPs / (chips * peak)
  memory     = bytes / (chips * HBM)
  collective = coll_bytes / (chips * link_bw)     [coll_bytes: per-chip sum]
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_F32 = 98.5e12
HBM_BW = 819e9
ICI_BW = 50e9
# host-memory bandwidth model for the CPU CI containers (DDR4/DDR5 class,
# single socket): benches on CPU report achieved-vs-bound against this
CPU_MEM_BW = 50e9
# per-collective launch/sync latency (paper §3.3: ~7.5 us per sync+comm+launch
# on A100+IB; TPU ICI hops are faster — 2 us models dispatch+first-hop)
COLL_LATENCY = 2e-6

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^=]*\)|tuple\(|[\w\[\]{},]+)\s+)?([a-z][a-z0-9\-]*)\(")
_CALL_KEYS = ("to_apply", "calls", "condition", "body", "branch_computations")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in a type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        d = _DTYPE_BYTES.get(m.group(1), 4)
        n = 1
        if m.group(2):
            for x in m.group(2).split(","):
                n *= int(x)
        total += d * n
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if m is None:
        return None
    return [int(x) for x in m.group(2).split(",") if x]


# source tags for byte attribution (matched against op_name metadata);
# each tag with a Pallas kernel can be analytically substituted in §Perf
_SOURCE_TAGS = ("wkv", "flash_attention", "mamba", "_ssm_scan", "moe_apply",
                "block_thomas", "solve_r", "solve_w", "gls_step",
                "run_external", "horizontal_advdiff", "adamw", "logsumexp")


def _source_tag(line: str) -> str:
    m = re.search(r'op_name="([^"]+)"', line)
    if not m:
        return "other"
    nm = m.group(1)
    for tag in _SOURCE_TAGS:
        if tag in nm:
            return tag
    return "other"


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_collectives: int = 0
    bytes_by_source: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add_bytes(self, b: float, tag: str):
        self.bytes += b
        self.bytes_by_source[tag] = self.bytes_by_source.get(tag, 0.0) + b

    def add(self, o: "HloStats", f: float = 1.0, include_bytes: bool = True):
        self.flops += f * o.flops
        self.coll_bytes += f * o.coll_bytes
        self.n_collectives += int(f * o.n_collectives)
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + f * v
        if include_bytes:
            self.bytes += f * o.bytes
            for k, v in o.bytes_by_source.items():
                self.bytes_by_source[k] = self.bytes_by_source.get(k, 0.0) \
                    + f * v


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.types: Dict[str, str] = {}   # %var -> type string

    def add_line(self, line: str):
        self.lines.append(line)
        m = _DEF_RE.match(line)
        if m:
            rest = m.group(2)
            # type string precedes the op name: "f32[2,3]{1,0} dot(...)"
            tm = _SHAPE_RE.search(rest)
            if tm is not None:
                # capture full leading type expr up to the op token
                opm = re.search(r"\)?\s+[a-z][a-z0-9\-]*\(", rest)
                tstr = rest[:opm.start() + 1] if opm else rest
                self.types[m.group(1)] = tstr


def _split_computations(text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")
                                or s.startswith("%")):
            nm = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", s)
            if nm:
                cur = _Computation(nm.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry = cur.name
            continue
        if s == "}" or s.startswith("} "):
            cur = None
            continue
        if cur is not None and "=" in s:
            cur.add_line(s)
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _operand_names(line: str, op: str) -> List[str]:
    """Names inside the op's (...) argument list."""
    idx = line.find(f" {op}(")
    if idx < 0:
        return []
    depth = 0
    args = ""
    for ch in line[idx + len(op) + 2:]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        args += ch
    return re.findall(r"%?([\w\.\-]+)", args)


def _dot_flops(line: str, comp: _Computation) -> float:
    out_dims = _first_shape_dims(line.split("=", 1)[1])
    if out_dims is None:
        return 0.0
    ops = _operand_names(line, "dot")
    if not ops:
        return 0.0
    lhs_t = comp.types.get(ops[0])
    if lhs_t is None:
        return 0.0
    lhs_dims = _first_shape_dims(lhs_t)
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if lhs_dims is None or mm is None:
        return 0.0
    contract = 1
    for ci in mm.group(1).split(","):
        if ci:
            contract *= lhs_dims[int(ci)]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contract


# ops that represent real memory traffic at fusion granularity. Virtual/
# layout ops (reshape, bitcast, broadcast, iota, get-tuple-element) and
# standalone elementwise (always fused on TPU) are excluded.
_MEM_OPS = ("fusion", "dot", "convolution", "custom-call", "scatter",
            "gather", "sort", "transpose", "copy",
            "dynamic-slice", "dynamic-update-slice", "concatenate",
            "pad", "slice", "select-and-scatter", "reduce-window", "rng",
            "cholesky", "triangular-solve", "reduce")


def _op_bytes(line: str, op: str, comp: _Computation) -> float:
    """Output + operand buffer bytes (symbol-table resolved)."""
    out_b = _shape_elems_bytes(line.split("=", 1)[1].split(f" {op}(")[0])
    in_b = 0
    for nm in _operand_names(line, op):
        t = comp.types.get(nm)
        if t:
            in_b += _shape_elems_bytes(t)
    return float(out_b + in_b)


def _trip_count(line: str, comps: Dict[str, _Computation]) -> int:
    m = re.search(r'known_trip_count=\{"?n"?[:=]"?(\d+)"?\}', line)
    if m:
        return int(m.group(1))
    m = re.search(r"trip_count=(\d+)", line)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w\.\-]+)", line)
    if cm and cm.group(1) in comps:
        comp = comps[cm.group(1)]
        consts = []
        for cl in comp.lines:
            mm = re.search(r"constant\((\d+)\)", cl)
            if mm:
                consts.append(int(mm.group(1)))
        if consts:
            return max(consts)
    return 1


def peak_bandwidth(platform: Optional[str] = None) -> float:
    """Memory-bandwidth bound (bytes/s) for achieved-vs-bound reporting.

    platform defaults to the ambient JAX backend.  TPU -> HBM, anything
    else -> the CPU host-memory model; the quotient achieved/bound is the
    bench artifact's roofline fraction."""
    if platform is None:
        import jax
        platform = jax.default_backend()
    return HBM_BW if platform == "tpu" else CPU_MEM_BW


def analyze_hlo_text(text: str) -> HloStats:
    """Aggregate per-device FLOPs/bytes/collective-bytes, loop-expanded."""
    comps, entry = _split_computations(text)
    memo: Dict[str, HloStats] = {}

    def visit(name: str, depth: int = 0) -> HloStats:
        if name in memo:
            return memo[name]
        agg = HloStats(coll_by_kind={}, bytes_by_source={})
        memo[name] = agg
        if name not in comps or depth > 64:
            return agg
        comp = comps[name]
        for line in comp.lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            rest = m.group(2)
            opm = re.search(r"(?:^|\s)([a-z][a-z0-9\-]*)\(", rest)
            if opm is None:
                continue
            op = opm.group(1)
            if op == "dot":
                agg.flops += _dot_flops(line, comp)
                agg.add_bytes(_op_bytes(line, op, comp), _source_tag(line))
            elif op == "convolution":
                out_dims = _first_shape_dims(rest) or []
                ops_ = _operand_names(line, op)
                ker = 1
                if len(ops_) >= 2 and ops_[1] in comp.types:
                    kd = _first_shape_dims(comp.types[ops_[1]]) or []
                    for d in kd:
                        ker *= d
                out = 1
                for d in out_dims:
                    out *= d
                agg.flops += 2.0 * out * ker
                agg.add_bytes(_op_bytes(line, op, comp), _source_tag(line))
            elif any(op.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                buf = _shape_elems_bytes(rest.split(f" {op}(")[0])
                factor = 2.0 if kind == "all-reduce" else 1.0
                cb = factor * buf
                agg.coll_bytes += cb
                agg.n_collectives += 1
                agg.coll_by_kind[kind] = agg.coll_by_kind.get(kind, 0.0) + cb
                agg.add_bytes(buf, _source_tag(line))
            elif op in _MEM_OPS:
                agg.add_bytes(_op_bytes(line, op, comp), _source_tag(line))
            # recurse into called computations.  Bytes only flow through
            # CONTROL-FLOW edges (while bodies/conditions, branches): ops
            # inside fusion computations live in registers/VMEM — counting
            # them double-counts the fusion op's operand/result traffic.
            # FLOPs flow through all edges (a dot inside a fusion is real).
            mult = _trip_count(line, comps) if "body=" in line else 1
            for key in _CALL_KEYS:
                for ref in re.findall(key + r"=\{?%?([\w\.\-]+)", line):
                    if ref in comps and ref != name:
                        f = mult if key == "body" else 1
                        inc_b = key in ("body", "condition",
                                        "branch_computations")
                        agg.add(visit(ref, depth + 1), f, include_bytes=inc_b)
        memo[name] = agg
        return agg

    return visit(entry) if entry else HloStats(coll_by_kind={},
                                               bytes_by_source={})


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float      # bandwidth term + latency term
    flops: float
    bytes: float
    coll_bytes: float
    chips: int
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    coll_bw_s: float = 0.0
    coll_latency_s: float = 0.0
    n_collectives: int = 0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Simple no-overlap upper bound = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Useful model throughput vs peak at the modelled step time."""
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops / self.step_time_s) / (
            self.chips * PEAK_FLOPS_BF16)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["step_time_s"] = self.step_time_s
        d["roofline_fraction"] = self.roofline_fraction()
        return d


def roofline_from_stats(stats: HloStats, chips: int,
                        model_flops: float = 0.0,
                        peak=PEAK_FLOPS_BF16,
                        cost_analysis_flops: float = 0.0) -> Roofline:
    """stats are for the per-device (SPMD) program: flops/bytes are per chip;
    collective bytes are per-chip wire traffic.

    The compute term takes max(parsed-dot FLOPs, cost_analysis FLOPs): the
    DG ocean code has no dot ops (elementwise assembly — only cost_analysis
    sees it, loop-undercounted = lower bound), LM stacks are dot-dominated
    (cost_analysis misses the x n_layers loop — the parse fixes it).
    The collective term adds a latency component n_collectives*COLL_LATENCY —
    the paper's 2D-mode Amdahl wall is latency, not bandwidth."""
    flops_pc = max(stats.flops, cost_analysis_flops or 0.0)
    compute = flops_pc / peak
    memory = stats.bytes / HBM_BW
    coll_bw = stats.coll_bytes / ICI_BW
    coll_lat = stats.n_collectives * COLL_LATENCY
    total_flops = flops_pc * chips
    return Roofline(
        compute_s=compute, memory_s=memory,
        collective_s=coll_bw + coll_lat,
        flops=total_flops, bytes=stats.bytes * chips,
        coll_bytes=stats.coll_bytes * chips, chips=chips,
        model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
        coll_bw_s=coll_bw, coll_latency_s=coll_lat,
        n_collectives=stats.n_collectives)


def model_flops_estimate(arch, shape, n_total: int, n_active: int) -> float:
    """MODEL_FLOPS: 6 N D (train), 2 N D (prefill), decode: 2 N B + KV reads."""
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * B * T
    if shape.kind == "prefill":
        return 2.0 * n_active * B * T
    flops = 2.0 * n_active * B
    if arch.family not in ("ssm",):
        n_attn_layers = arch.n_layers if arch.attn_period == 0 else \
            arch.n_layers // arch.attn_period
        flops += 4.0 * B * T * n_attn_layers * arch.n_heads * arch.hd
    return flops
