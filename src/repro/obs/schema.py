"""Schema for the flight-recorder JSONL metrics stream.

One JSON object per line.  Every record has:

  ts      float   unix seconds (host clock at emission)
  kind    str     one of KINDS
  name    str     dotted metric name, e.g. "kernel_dispatch", "obs.cfl_2d"
  value           kind-dependent payload (see below)
  labels  dict    optional {str: str|int|float|bool} dimensions
  step    int     optional simulation/train step the record belongs to

Per-kind ``value``:

  counter      number >= 0 (cumulative; emitted as a snapshot by flush())
  gauge        number or null (null = value was non-finite on device)
  histogram    {"count": int, "sum": num, "min": num, "max": num,
                "p50": num, "p90": num}   — units in the name (..._us, ...)
  event        any JSON object (free-form, e.g. monitor violations)
  diagnostics  {str: number|bool|null} — the physics Diagnostics snapshot

Non-finite floats are sanitised to null by the sink (strict JSON) — the
physics NaN signal travels as the explicit ``nonfinite`` bool inside
diagnostics records, never as a bare NaN literal.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Tuple

KINDS = ("counter", "gauge", "histogram", "event", "diagnostics")

HIST_KEYS = ("count", "sum", "min", "max", "p50", "p90")


class SchemaError(ValueError):
    """A metrics record does not conform to the flight-recorder schema."""


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _check_labels(labels) -> None:
    if not isinstance(labels, dict):
        raise SchemaError(f"labels must be a dict, got {type(labels).__name__}")
    for k, v in labels.items():
        if not isinstance(k, str):
            raise SchemaError(f"label key {k!r} is not a string")
        if not isinstance(v, (str, int, float, bool)):
            raise SchemaError(f"label {k!r} has non-scalar value {v!r}")


def validate_record(rec: Any) -> None:
    """Raise SchemaError if ``rec`` is not a valid flight-recorder record."""
    if not isinstance(rec, dict):
        raise SchemaError(f"record must be an object, got {type(rec).__name__}")
    for req in ("ts", "kind", "name"):
        if req not in rec:
            raise SchemaError(f"missing required field {req!r}")
    if not _is_num(rec["ts"]):
        raise SchemaError(f"ts must be a number, got {rec['ts']!r}")
    kind = rec["kind"]
    if kind not in KINDS:
        raise SchemaError(f"kind must be one of {KINDS}, got {kind!r}")
    name = rec["name"]
    if not isinstance(name, str) or not name:
        raise SchemaError(f"name must be a non-empty string, got {name!r}")
    if "labels" in rec:
        _check_labels(rec["labels"])
    if "step" in rec and rec["step"] is not None \
            and not isinstance(rec["step"], int):
        raise SchemaError(f"step must be an int, got {rec['step']!r}")

    v = rec.get("value")
    if kind == "counter":
        if not _is_num(v) or v < 0:
            raise SchemaError(f"counter value must be a number >= 0, got {v!r}")
    elif kind == "gauge":
        if v is not None and not _is_num(v):
            raise SchemaError(f"gauge value must be a number or null, got {v!r}")
    elif kind == "histogram":
        if not isinstance(v, dict):
            raise SchemaError(f"histogram value must be an object, got {v!r}")
        for k in HIST_KEYS:
            if k not in v:
                raise SchemaError(f"histogram value missing key {k!r}")
            if not _is_num(v[k]):
                raise SchemaError(f"histogram {k!r} must be a number, "
                                  f"got {v[k]!r}")
        if v["count"] < 0 or v["min"] > v["max"]:
            raise SchemaError(f"histogram value inconsistent: {v!r}")
    elif kind == "event":
        if v is not None and not isinstance(v, dict):
            raise SchemaError(f"event value must be an object or null, "
                              f"got {v!r}")
    elif kind == "diagnostics":
        if not isinstance(v, dict):
            raise SchemaError(f"diagnostics value must be an object, "
                              f"got {v!r}")
        for k, x in v.items():
            if not isinstance(k, str):
                raise SchemaError(f"diagnostics key {k!r} is not a string")
            if x is not None and not isinstance(x, (int, float, bool)):
                raise SchemaError(f"diagnostics {k!r} has non-scalar value "
                                  f"{x!r}")


def sanitize(obj: Any) -> Any:
    """Recursively replace non-finite floats with None (strict JSON)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    return obj


def validate_lines(lines: Iterable[str]) -> Tuple[int, List[Tuple[int, str]]]:
    """Validate an iterable of JSONL lines.

    Returns (n_valid_records, [(lineno, error), ...]); blank lines are
    skipped.  Parsing is strict JSON (NaN/Infinity literals are errors —
    the sink sanitises them to null at write time)."""
    n_ok = 0
    errors: List[Tuple[int, str]] = []
    for i, line in enumerate(lines, start=1):
        s = line.strip()
        if not s:
            continue
        try:
            rec = json.loads(
                s, parse_constant=lambda c: (_ for _ in ()).throw(
                    SchemaError(f"non-strict JSON literal {c!r}")))
            validate_record(rec)
        except (json.JSONDecodeError, SchemaError) as e:
            errors.append((i, str(e)))
            continue
        n_ok += 1
    return n_ok, errors


def validate_file(path: str) -> Tuple[int, List[Tuple[int, str]]]:
    """Validate a JSONL metrics file; see validate_lines."""
    with open(path) as fh:
        return validate_lines(fh)
