"""On-device physics monitors: a ``Diagnostics`` pytree computed inside the
jitted step at near-zero cost, and a host-side ``MonitorPolicy``.

The monitors are the quantities that tell you a run has gone physically
wrong *before* the output does (paper §4: the headline numbers are only
meaningful for physically sane runs):

  * total water volume  ∫ H dA          (exactly conserved in a closed basin)
  * tracer masses       ∫ T dV, ∫ S dV  (conserved to roundoff by the scheme)
  * tracer min/max      (DG advection of a tracer must stay inside the
                         initial bounds up to the diffusion terms)
  * max |eta|, max horizontal speed
  * external-mode wave CFL  (|u| + sqrt(gH)) * dt_2d / h   per element
  * a non-finite flag WITH localisation: the first offending field and the
    2D cell (triangle) it occurs in — argmax on device, so a NaN report
    costs two int32 scalars, not a host readback of the state.

All reductions are O(state) elementwise work fused into the step by XLA —
measured overhead on the CPU fused path is well under the 3%% budget.

Host-side, ``MonitorPolicy.check`` turns a Diagnostics into violation
events: warn, halt (raise ``MonitorHalt`` — which
``runtime/fault_tolerance.py`` treats as a step failure and answers with
restore-and-retry), or silent collection, and mirrors everything into the
metrics registry.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import geometry as G
from ..core import stepper, vertical
from ..core.extrusion import VGrid, layer_geometry

# localisation priority: first listed field wins when several go bad at once
FIELDS = ("eta", "qx", "qy", "ux", "uy", "T", "S", "turb_k", "turb_eps")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Diagnostics:
    """Scalar physics monitors for one model state (all on-device)."""
    time: jax.Array         # model time [s]
    volume: jax.Array       # total water volume ∫ H dA [m^3]
    mass_T: jax.Array       # ∫ T dV (tracer content)
    mass_S: jax.Array
    T_min: jax.Array
    T_max: jax.Array
    S_min: jax.Array
    S_max: jax.Array
    eta_max: jax.Array      # max |eta| [m]
    speed_max: jax.Array    # max horizontal |u| [m/s]
    cfl_2d: jax.Array       # max external-mode wave CFL over elements
    nonfinite: jax.Array    # bool: any NaN/Inf in the prognostic state
    bad_field: jax.Array    # int32 index into FIELDS (-1 if finite)
    bad_cell: jax.Array     # int32 triangle index (-1 if finite)


def _colwise_nonfinite(x: jax.Array) -> jax.Array:
    """(…, nt) -> (nt,) bool: any non-finite entry in each cell column."""
    bad = ~jnp.isfinite(x)
    return bad.reshape(-1, x.shape[-1]).any(axis=0)


def compute(geom: G.Geom2D, vg: VGrid, cfg: stepper.OceanConfig,
            st: stepper.OceanState) -> Diagnostics:
    """Pure-jnp monitor bundle; call inside jit right after the step."""
    vge = layer_geometry(vg, st.ext.eta, cfg.h_min)

    # conservation integrals: ∫ of a P1 field over a triangle is
    # area * mean(vertex values); tracer content uses the same 3D mass
    # matrix the stepper conserves with
    volume = (geom.area * vge.H.mean(axis=0)).sum()
    mass_T = vertical.mass_apply3d(geom, vge.jz, st.T).sum()
    mass_S = vertical.mass_apply3d(geom, vge.jz, st.S).sum()

    speed2 = st.ux ** 2 + st.uy ** 2
    speed_max = jnp.sqrt(speed2.max())

    # external-mode wave CFL per element: the 2D burst runs m_2d substeps
    # per internal dt, element length scale h = 2 area / longest edge
    dt2d = cfg.dt / max(cfg.m_2d, 1)
    h = 2.0 * geom.area / geom.edge_len.max(axis=0)
    c = jnp.sqrt(G.G_GRAV * vge.H.max(axis=0))
    umax_el = jnp.sqrt(speed2.reshape(-1, geom.nt).max(axis=0))
    cfl_2d = ((c + umax_el) * dt2d / h).max()

    # non-finite localisation: stack per-cell badness of every prognostic
    # field; row-major argmax -> (first bad field, first bad cell in it)
    fields = dict(eta=st.ext.eta, qx=st.ext.qx, qy=st.ext.qy,
                  ux=st.ux, uy=st.uy, T=st.T, S=st.S,
                  turb_k=st.turb_k, turb_eps=st.turb_eps)
    bad = jnp.stack([_colwise_nonfinite(fields[f]) for f in FIELDS])
    any_bad = bad.any()
    idx = jnp.argmax(bad.reshape(-1)).astype(jnp.int32)
    nt = geom.nt
    bad_field = jnp.where(any_bad, idx // nt, jnp.int32(-1))
    bad_cell = jnp.where(any_bad, idx % nt, jnp.int32(-1))

    return Diagnostics(
        time=st.time, volume=volume, mass_T=mass_T, mass_S=mass_S,
        T_min=st.T.min(), T_max=st.T.max(),
        S_min=st.S.min(), S_max=st.S.max(),
        eta_max=jnp.abs(st.ext.eta).max(), speed_max=speed_max,
        cfl_2d=cfl_2d, nonfinite=any_bad, bad_field=bad_field,
        bad_cell=bad_cell)


def step_with_diagnostics(geom: G.Geom2D, vg: VGrid,
                          cfg: stepper.OceanConfig, st: stepper.OceanState,
                          forcing: Optional[stepper.Forcing3D] = None,
                          **kw) -> Tuple[stepper.OceanState, Diagnostics]:
    """One stepper.step + the monitor bundle of the NEW state, in one jit
    region — the diagnostics fuse into the step's epilogue."""
    if forcing is None:
        forcing = stepper.Forcing3D()
    st1 = stepper.step(geom, vg, cfg, st, forcing, **kw)
    with jax.named_scope("obs.diagnostics"):
        diag = compute(geom, vg, cfg, st1)
    return st1, diag


def to_dict(diag: Diagnostics) -> Dict[str, Any]:
    """Host-side python scalars (one device sync for the whole bundle)."""
    leaves = jax.device_get(diag)
    out: Dict[str, Any] = {}
    for f in dataclasses.fields(Diagnostics):
        v = getattr(leaves, f.name)
        if f.name == "nonfinite":
            out[f.name] = bool(v)
        elif f.name in ("bad_field", "bad_cell"):
            out[f.name] = int(v)
        else:
            out[f.name] = float(v)
    bf = out["bad_field"]
    out["bad_field_name"] = FIELDS[bf] if 0 <= bf < len(FIELDS) else None
    return out


class MonitorHalt(RuntimeError):
    """Raised by MonitorPolicy(on_violation='halt'); carries the diagnostics
    dict so fault handling can log/act on the physics reason."""

    def __init__(self, violations: List[dict], diag: Dict[str, Any]):
        self.violations = violations
        self.diagnostics = diag
        super().__init__("physics monitor violation: " + "; ".join(
            v["rule"] + (f" ({v['detail']})" if v.get("detail") else "")
            for v in violations))


@dataclasses.dataclass
class MonitorPolicy:
    """Host-side thresholds + what to do when one trips.

    ``on_violation``: "warn" (warnings.warn, keep running), "halt" (raise
    MonitorHalt — the fault-tolerance runner restores a checkpoint and
    retries), or "silent" (collect only, caller inspects the return).
    Conservation drift limits are relative to the reference values captured
    on the FIRST check (or set explicitly via ``reference``)."""
    cfl_max: Optional[float] = 1.0
    eta_max: Optional[float] = None          # [m]
    speed_max: Optional[float] = None        # [m/s]
    tracer_bounds: Optional[Dict[str, Tuple[float, float]]] = None
    volume_drift_max: Optional[float] = None     # relative
    mass_drift_max: Optional[float] = None       # relative, T and S
    on_violation: str = "warn"
    reference: Optional[Dict[str, float]] = None

    def check(self, diag, step: Optional[int] = None,
              registry=None) -> List[dict]:
        """Evaluate all configured rules; emit events; warn/halt per policy.

        ``diag`` is a Diagnostics pytree or an already-converted dict."""
        d = diag if isinstance(diag, dict) else to_dict(diag)
        if self.reference is None:
            self.reference = {k: d[k] for k in ("volume", "mass_T", "mass_S")}
        v: List[dict] = []

        def rule(name, value, limit, detail=""):
            v.append(dict(rule=name, value=value, limit=limit, detail=detail))

        if d["nonfinite"]:
            rule("nonfinite", 1.0, 0.0,
                 f"field={d['bad_field_name']} cell={d['bad_cell']}")
        if self.cfl_max is not None and d["cfl_2d"] > self.cfl_max:
            rule("cfl_2d", d["cfl_2d"], self.cfl_max)
        if self.eta_max is not None and d["eta_max"] > self.eta_max:
            rule("eta_max", d["eta_max"], self.eta_max)
        if self.speed_max is not None and d["speed_max"] > self.speed_max:
            rule("speed_max", d["speed_max"], self.speed_max)
        for tr, (lo, hi) in (self.tracer_bounds or {}).items():
            if d[f"{tr}_min"] < lo:
                rule(f"{tr}_min", d[f"{tr}_min"], lo, "monotonicity floor")
            if d[f"{tr}_max"] > hi:
                rule(f"{tr}_max", d[f"{tr}_max"], hi, "monotonicity ceiling")
        for key, lim in (("volume", self.volume_drift_max),
                         ("mass_T", self.mass_drift_max),
                         ("mass_S", self.mass_drift_max)):
            if lim is None:
                continue
            ref = self.reference[key]
            drift = abs(d[key] - ref) / max(abs(ref), 1e-30)
            if drift > lim:
                rule(f"{key}_drift", drift, lim)

        if registry is not None:
            registry.diagnostics("physics", d, step=step)
            for viol in v:
                registry.event("monitor.violation", viol, step=step)
        if v:
            if self.on_violation == "halt":
                raise MonitorHalt(v, d)
            if self.on_violation == "warn":
                warnings.warn(
                    "physics monitor violation(s): "
                    + "; ".join(f"{x['rule']}={x['value']:.4g} "
                                f"(limit {x['limit']:.4g})" for x in v),
                    RuntimeWarning, stacklevel=2)
        return v
