"""Flight-recorder metrics: counters / gauges / histograms + a JSONL sink.

A ``Registry`` owns named instruments and (optionally) a ``JsonlSink``:

    reg = metrics.Registry(sink=metrics.JsonlSink(path))
    reg.counter("kernel_dispatch", op="solve_r", backend="pallas").inc()
    reg.gauge("runner.step_time_ema_s").set(0.12)
    with reg.timer("stage_time_us", stage="step"):
        ...                         # host wall time -> histogram observe
    reg.event("monitor.violation", {"rule": "cfl"}, step=3)   # immediate
    reg.diagnostics("physics", diag_dict, step=3)             # immediate
    reg.flush(step=3)               # snapshot counters/gauges/histograms

Counters incremented from inside jit-traced Python (the kernel dispatch
sites in ``kernels/ops.py``, the halo exchange in ``distributed/halo.py``)
count *call sites traced into each compiled program* — tracing happens once
per (re)compile, so these are per-program dispatch counts, not per-execution
counts.  That is exactly the quantity a launch-latency model needs (paper
§3.3: dispatch count x per-launch overhead).

The module-level default registry is what the instrumented library paths
write to; ``configure(path)`` attaches a sink (until then instruments
aggregate in memory and flush() is a no-op), ``reset()`` clears it (tests).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

from . import schema

# bounded per-histogram sample reservoir (most-recent samples win)
_HIST_CAP = 4096


class JsonlSink:
    """Append-only JSONL writer (thread-safe, line-buffered)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._lock = threading.Lock()
        self._fh = open(path, "a", buffering=1)

    def write(self, rec: Dict[str, Any]) -> None:
        line = json.dumps(schema.sanitize(rec), allow_nan=False,
                          separators=(",", ":"))
        with self._lock:
            self._fh.write(line + "\n")

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


class Counter:
    def __init__(self):
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    def __init__(self):
        self._v: Optional[float] = None

    def set(self, v: float) -> None:
        self._v = float(v)

    @property
    def value(self) -> Optional[float]:
        return self._v


class Histogram:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples = []

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            if len(self._samples) >= _HIST_CAP:
                self._samples.pop(0)
            self._samples.append(v)

    def _quantile(self, q: float) -> float:
        s = sorted(self._samples)
        if not s:
            return 0.0
        i = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
        return s[i]

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            if self.count == 0:
                return dict(count=0, sum=0.0, min=0.0, max=0.0,
                            p50=0.0, p90=0.0)
            return dict(count=self.count, sum=self.sum, min=self.min,
                        max=self.max, p50=self._quantile(0.5),
                        p90=self._quantile(0.9))


def _key(name: str, labels: Dict[str, Any]) -> Tuple:
    return (name,) + tuple(sorted(labels.items()))


class _Timer:
    def __init__(self, hist: Histogram, scale: float):
        self._hist = hist
        self._scale = scale

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe((time.perf_counter() - self._t0) * self._scale)
        return False


class Registry:
    """Named instruments + immediate-mode events over one optional sink."""

    def __init__(self, sink: Optional[JsonlSink] = None):
        self.sink = sink
        self._lock = threading.Lock()
        self._counters: Dict[Tuple, Tuple[Counter, str, dict]] = {}
        self._gauges: Dict[Tuple, Tuple[Gauge, str, dict]] = {}
        self._hists: Dict[Tuple, Tuple[Histogram, str, dict]] = {}

    def _get(self, store, cls, name: str, labels: dict):
        k = _key(name, labels)
        with self._lock:
            if k not in store:
                store[k] = (cls(), name, dict(labels))
            return store[k][0]

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(self._hists, Histogram, name, labels)

    def timer(self, name: str, scale: float = 1e6, **labels) -> _Timer:
        """Context manager: host wall time -> histogram observe.

        Default scale 1e6 = microseconds (name the metric ``*_us``)."""
        return _Timer(self.histogram(name, **labels), scale)

    # -- immediate-mode records ----------------------------------------------
    def _write(self, rec: Dict[str, Any]) -> None:
        if self.sink is not None:
            self.sink.write(rec)

    def _rec(self, kind: str, name: str, value, labels: dict,
             step: Optional[int]) -> Dict[str, Any]:
        rec: Dict[str, Any] = dict(ts=time.time(), kind=kind, name=name,
                                   value=value)
        if labels:
            rec["labels"] = labels
        if step is not None:
            rec["step"] = int(step)
        return rec

    def event(self, name: str, value: Optional[dict] = None,
              step: Optional[int] = None, **labels) -> None:
        self._write(self._rec("event", name, value, labels, step))

    def diagnostics(self, name: str, values: Dict[str, Any],
                    step: Optional[int] = None, **labels) -> None:
        self._write(self._rec("diagnostics", name, values, labels, step))

    # -- snapshots ------------------------------------------------------------
    def flush(self, step: Optional[int] = None) -> None:
        """Write one snapshot record per instrument to the sink."""
        if self.sink is None:
            return
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._hists.values())
        for c, name, labels in counters:
            self._write(self._rec("counter", name, c.value, labels, step))
        for g, name, labels in gauges:
            if g.value is not None:
                self._write(self._rec("gauge", name, g.value, labels, step))
        for h, name, labels in hists:
            if h.count:
                self._write(self._rec("histogram", name, h.snapshot(),
                                      labels, step))

    def snapshot(self) -> Dict[str, Any]:
        """In-memory view {kind: {name{labels}: value}} (tests/CLIs)."""
        def fmt(name, labels):
            if not labels:
                return name
            return name + "{" + ",".join(f"{k}={v}" for k, v in
                                         sorted(labels.items())) + "}"
        out: Dict[str, Any] = {"counter": {}, "gauge": {}, "histogram": {}}
        with self._lock:
            for c, name, labels in self._counters.values():
                out["counter"][fmt(name, labels)] = c.value
            for g, name, labels in self._gauges.values():
                out["gauge"][fmt(name, labels)] = g.value
            for h, name, labels in self._hists.values():
                out["histogram"][fmt(name, labels)] = h.snapshot()
        return out

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()


# --------------------------------------------------------------------------
# module-level default registry (what the instrumented library writes to)
# --------------------------------------------------------------------------
_default = Registry()


def default() -> Registry:
    return _default


def configure(path: Optional[str] = None) -> Registry:
    """Attach a JSONL sink at ``path`` to the default registry (keeps the
    accumulated in-memory instruments). ``path=None`` detaches the sink."""
    if _default.sink is not None:
        _default.sink.close()
    _default.sink = JsonlSink(path) if path else None
    return _default


def reset() -> Registry:
    """Drop all instruments and the sink of the default registry (tests)."""
    global _default
    if _default.sink is not None:
        _default.sink.close()
    _default = Registry()
    return _default
