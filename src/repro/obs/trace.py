"""Staged tracing helpers: named scopes for XLA/Pallas profiles + an opt-in
``jax.profiler`` trace session that lands in a run directory.

``annotate(name)`` is safe both inside jit-traced code (adds a
``jax.named_scope`` so the op shows up under that name in compiled HLO and
profiler timelines) and on the host (adds a ``TraceAnnotation`` span to any
active profiler trace).  The library hot path uses bare ``jax.named_scope``
directly — zero runtime cost, pure trace-time metadata.

``trace_session`` wraps ``jax.profiler.start_trace/stop_trace``; it is
opt-in: enabled explicitly, or via the ``REPRO_TRACE=1`` environment
variable (run directory override: ``REPRO_RUN_DIR``).  Profiles land in
``<run_dir>/plugins/profile/...`` — point TensorBoard or xprof at the run
directory.
"""
from __future__ import annotations

import contextlib
import datetime
import os
from typing import Iterator, Optional

import jax

ENV_TRACE = "REPRO_TRACE"
ENV_RUN_DIR = "REPRO_RUN_DIR"
DEFAULT_RUNS_ROOT = "runs"


def trace_enabled() -> bool:
    return os.environ.get(ENV_TRACE, "0") not in ("", "0", "false", "False")


def default_run_dir(prefix: str = "trace") -> str:
    env = os.environ.get(ENV_RUN_DIR)
    if env:
        return env
    stamp = datetime.datetime.now().strftime("%Y%m%d-%H%M%S")
    return os.path.join(DEFAULT_RUNS_ROOT, f"{prefix}-{stamp}-{os.getpid()}")


@contextlib.contextmanager
def annotate(name: str) -> Iterator[None]:
    """named_scope (trace-time HLO metadata) + TraceAnnotation (host span)."""
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


@contextlib.contextmanager
def trace_session(run_dir: Optional[str] = None,
                  enabled: Optional[bool] = None) -> Iterator[Optional[str]]:
    """Opt-in profiler trace over the enclosed block.

    Yields the run directory when tracing is active, else None.  ``enabled``
    defaults to the REPRO_TRACE environment toggle, so harnesses can wrap
    their hot section unconditionally and let the environment decide."""
    if enabled is None:
        enabled = trace_enabled()
    if not enabled:
        yield None
        return
    run_dir = run_dir or default_run_dir()
    os.makedirs(run_dir, exist_ok=True)
    jax.profiler.start_trace(run_dir)
    try:
        yield run_dir
    finally:
        jax.profiler.stop_trace()
