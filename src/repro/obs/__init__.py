"""Flight recorder: staged tracing, on-device physics monitors, metrics.

Layering: ``metrics``/``schema``/``trace`` are dependency-free of the model
code (the kernel layer imports them for dispatch counting), while
``diagnostics`` sits on top of ``core`` — so it is loaded lazily here to
keep ``import repro.obs.metrics`` cycle-free from inside ``kernels/ops.py``.
"""
from __future__ import annotations

from . import metrics, schema, trace                        # noqa: F401

_LAZY = ("diagnostics",)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["metrics", "schema", "trace", "diagnostics"]
