"""The 10 assigned architectures (public pool; sources per entry).

Known simplifications (documented; computational shapes preserved):
  * starcoder2/hubert use RMSNorm instead of parametric LayerNorm,
  * gemma2's GeGLU is realised as SwiGLU (identical matmul shapes),
  * jamba places its attention layer at index attn_period//2 of each
    8-layer block and MoE on odd sub-layers (1:7 attn:mamba, MoE every 2 —
    the arXiv:2403.19887 ratios).
"""
from __future__ import annotations

from ..models.mamba import MambaCfg
from ..models.moe import MoeCfg
from ..models.rwkv import RwkvCfg
from .base import ArchConfig

# [arXiv:2404.16821; hf] InternViT frontend is a stub (precomputed patch
# embeddings); backbone = InternLM2-20B geometry.
INTERNVL2_26B = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv=8, d_ff=16384, vocab=92553, head_dim=128,
    rope_theta=1e6, frontend="vlm", n_patches=256)

# [arXiv:2402.19173; hf] GQA kv=2, RoPE, GeLU MLP.
STARCODER2_3B = ArchConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, n_kv=2, d_ff=12288, vocab=49152, head_dim=128,
    rope_theta=1e5, act="gelu")

# [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
MISTRAL_LARGE_123B = ArchConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv=8, d_ff=28672, vocab=32768, head_dim=128,
    rope_theta=1e6)

# [arXiv:2408.00118; hf] local(4096)/global alternating, attn softcap 50,
# final-logit softcap 30, head_dim 256, tied embeddings.
GEMMA2_9B = ArchConfig(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv=8, d_ff=14336, vocab=256000, head_dim=256,
    window=4096, alt_local_global=True, softcap_attn=50.0,
    softcap_logits=30.0, tie_embeddings=True)

# [arXiv:2402.00838; hf] non-parametric LN, MHA, tied embeddings.
OLMO_1B = ArchConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv=16, d_ff=8192, vocab=50304, norm="nonparam",
    tie_embeddings=True)

# [arXiv:2403.19887; hf] Mamba+attn 1:7, MoE 16e top-2 every 2 layers.
JAMBA_15_LARGE_398B = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv=8, d_ff=24576, vocab=65536, head_dim=128,
    attn_period=8, moe_period=2,
    moe=MoeCfg(n_experts=16, top_k=2, d_ff=24576),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2))

# [arXiv:2106.07447; unverified] encoder-only; conv feature extractor is a
# stub (precomputed frame embeddings); masked-unit prediction over 504 units.
HUBERT_XLARGE = ArchConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv=16, d_ff=5120, vocab=504, encoder_only=True,
    causal=False, frontend="audio", act="gelu")

# [arXiv:2404.05892; hf] Finch: attention-free, data-dependent decay.
RWKV6_3B = ArchConfig(
    name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
    n_heads=40, n_kv=40, d_ff=8960, vocab=65536,
    rwkv=RwkvCfg(head_dim=64))

# [hf:microsoft/Phi-3.5-MoE-instruct; hf] 16 experts top-2 every layer.
PHI35_MOE_42B = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=6400, vocab=32064, head_dim=128,
    moe=MoeCfg(n_experts=16, top_k=2, d_ff=6400))

# [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 60 routed top-4 + 4 shared experts.
QWEN2_MOE_A27B = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv=16, d_ff=1408, vocab=151936,
    moe=MoeCfg(n_experts=60, top_k=4, d_ff=1408, n_shared=4))

ALL_ARCHS = {
    a.name: a for a in [
        INTERNVL2_26B, STARCODER2_3B, MISTRAL_LARGE_123B, GEMMA2_9B,
        OLMO_1B, JAMBA_15_LARGE_398B, HUBERT_XLARGE, RWKV6_3B,
        PHI35_MOE_42B, QWEN2_MOE_A27B,
    ]
}
