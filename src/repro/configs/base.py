"""Architecture configuration schema + the shape table for the assigned
architecture pool (system-prompt block; sources cited per config file)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..models.mamba import MambaCfg
from ..models.moe import MoeCfg
from ..models.rwkv import RwkvCfg


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    rope_theta: float = 1e4
    window: Optional[int] = None     # sliding window (gemma2 local layers)
    alt_local_global: bool = False   # gemma2: even layers local, odd global
    softcap_attn: Optional[float] = None
    softcap_logits: Optional[float] = None
    norm: str = "rms"                # rms | nonparam (olmo)
    act: str = "swiglu"              # swiglu | gelu
    causal: bool = True
    encoder_only: bool = False
    frontend: Optional[str] = None   # vlm | audio (stub embeddings)
    n_patches: int = 256             # vlm stub prefix length
    moe: Optional[MoeCfg] = None
    moe_period: int = 1              # apply MoE every k-th layer (jamba: 2)
    attn_period: int = 0             # hybrid: 1 attention layer per k (jamba 8)
    mamba: Optional[MambaCfg] = None
    rwkv: Optional[RwkvCfg] = None
    tie_embeddings: bool = False
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))


# ---- shape table -------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k only for sub-quadratic families; encoder-only has no decode
LONG_FAMILIES = ("ssm", "hybrid")


def applicable_shapes(arch: ArchConfig) -> Tuple[str, ...]:
    out = ["train_4k", "prefill_32k"]
    if not arch.encoder_only:
        out.append("decode_32k")
        if arch.family in LONG_FAMILIES:
            out.append("long_500k")
    return tuple(out)
