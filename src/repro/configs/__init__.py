"""Config registry: one module-level ArchConfig per assigned architecture
(also importable as repro.configs.<file>) + the paper's own ocean configs."""
import dataclasses

from .archs import ALL_ARCHS
from .base import SHAPES, ArchConfig, ShapeSpec, applicable_shapes


def get_arch(name: str) -> ArchConfig:
    if name not in ALL_ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ALL_ARCHS)}")
    return ALL_ARCHS[name]


def reduce_arch(arch: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width,
    few experts, tiny vocab), preserving the super-block program shape."""
    from ..models.model import block_program
    from ..models.moe import MoeCfg
    prog_len = len(block_program(arch))
    hd = 16
    n_heads = max(arch.n_heads and 4, 4)
    n_kv = 2 if arch.n_kv < arch.n_heads else n_heads
    changes = dict(
        n_layers=prog_len, d_model=n_heads * hd, n_heads=n_heads, n_kv=n_kv,
        d_ff=96, vocab=128, head_dim=hd, n_patches=4, window=(
            16 if arch.window else None), remat=False)
    if arch.moe is not None:
        changes["moe"] = MoeCfg(n_experts=4, top_k=2, d_ff=32,
                                n_shared=min(arch.moe.n_shared, 1))
    return dataclasses.replace(arch, **changes)
