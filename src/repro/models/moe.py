"""Mixture-of-Experts layers: top-k routing, dense (einsum) dispatch, shared
experts (qwen2-moe), load-balancing auxiliary loss.

Dispatch is the dense one-hot-combine formulation: per token a (E,)-weight
vector contracts against the expert-stacked FFN weights.  Under GSPMD this
shards cleanly either way the expert dimension is laid out:
  * expert-parallel (EP): experts sharded over `model` (phi3.5 16e/16,
    jamba 16e/16) — the combine einsum induces a reduce-scatter;
  * tensor-parallel fallback: d_ff sharded over `model` when E doesn't
    divide the axis (qwen2's 60 experts).
Capacity-style token dropping is not modelled (dense dispatch computes every
expert for every token at full fidelity on the roofline's FLOP side; the
dry-run cost model reports MoE 'useful' FLOPs as 6*N_active*D).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoeCfg:
    n_experts: int
    top_k: int
    d_ff: int                   # per-expert hidden size
    n_shared: int = 0           # always-on shared experts (qwen2)
    router_aux_coef: float = 0.01


def moe_params(rng, d_model, cfg: MoeCfg, act: str, dtype=jnp.bfloat16):
    E, F = cfg.n_experts, cfg.d_ff
    ks = jax.random.split(rng, 5)
    sc_in = 1.0 / (d_model ** 0.5)
    sc_out = 1.0 / (F ** 0.5)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E)) * sc_in).astype(
            jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, F)) * sc_in).astype(dtype),
        "w_in": (jax.random.normal(ks[2], (E, d_model, F)) * sc_in).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (E, F, d_model)) * sc_out).astype(dtype),
    }
    if cfg.n_shared > 0:
        Fs = F * cfg.n_shared
        k5, k6, k7 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(k5, (d_model, Fs)) * sc_in).astype(dtype),
            "w_in": (jax.random.normal(k6, (d_model, Fs)) * sc_in).astype(dtype),
            "w_out": (jax.random.normal(k7, (Fs, d_model)) * sc_out).astype(dtype),
        }
    return p


def moe_apply(p, x, cfg: MoeCfg, hidden_sharding=None):
    """x (B, T, D) -> (out, aux_loss).

    hidden_sharding: optional NamedSharding for the (B, T, E, F) dispatch
    intermediates.  For single-token decode, pinning (E@model, F@data) makes
    GSPMD gather the tiny activations and keep the expert weights fully
    2D-sharded — without it the partitioner all-gathers 100s of MB of expert
    weights per layer per token (the jamba decode_32k hillclimb)."""
    B, T, D = x.shape
    logits = (x.astype(jnp.float32) @ p["router"])        # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / topv.sum(axis=-1, keepdims=True)
    # combine weights (B, T, E): zero except top-k entries
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=probs.dtype)
    comb = jnp.einsum("btk,btke->bte", topv, onehot)

    # dense dispatch: every expert sees every token, weighted combine
    h_gate = jnp.einsum("btd,edf->btef", x, p["w_gate"])
    h_in = jnp.einsum("btd,edf->btef", x, p["w_in"])
    if hidden_sharding is not None:
        h_gate = jax.lax.with_sharding_constraint(h_gate, hidden_sharding)
        h_in = jax.lax.with_sharding_constraint(h_in, hidden_sharding)
    h = jax.nn.silu(h_gate) * h_in
    out = jnp.einsum("btef,efd,bte->btd", h, p["w_out"],
                     comb.astype(h.dtype))

    if cfg.n_shared > 0:
        s = p["shared"]
        hs = jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_in"])
        out = out + hs @ s["w_out"]

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    frac = onehot.sum(axis=2).mean(axis=(0, 1))           # (E,) token fraction
    pmean = probs.mean(axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac * pmean) * cfg.router_aux_coef
    return out.astype(x.dtype), aux
