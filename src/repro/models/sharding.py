"""Sharding rules: parameter / optimizer / batch PartitionSpecs per arch.

Default layout on the production mesh (DESIGN.md §6):
  * data parallel over ("pod", "data") for batches,
  * tensor parallel over "model": attention heads, MLP hidden, expert dim
    (EP) where divisible — qwen2's 60 experts fall back to FF-dim TP,
  * decode KV caches: batch over DP axes, sequence over "model"
    (flash-decode combine; long_500k shards the sequence over data+model),
  * ZeRO-1 flag: optimizer moments additionally sharded over "data" on the
    first divisible unsharded dim.

A dim is only sharded when its size divides the axis size — otherwise the
spec falls back to replication for that dim (no uneven GSPMD padding).
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from .model import Model


def _div(n: int, mesh_shape: Dict[str, int], axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh_shape[a]
    else:
        size = mesh_shape[axis]
    return n % size == 0


def _spec_for(path_keys: Tuple[str, ...], shape: Tuple[int, ...],
              mesh_shape: Dict[str, int], tp) -> P:
    """Parameter partition spec by name/shape pattern (pre-stacking)."""
    name = path_keys[-1]
    ndim = len(shape)

    def m(dim_idx, axis):
        return axis if _div(shape[dim_idx], mesh_shape, axis) else None

    if name in ("embed",):                       # (V, D)
        return P(m(0, tp), None)
    if name in ("head",):                        # (D, V)
        return P(None, m(1, tp))
    if name in ("vlm_proj", "audio_proj"):
        return P(None, m(1, tp))
    if name in ("wq", "wk", "wv"):               # (D, H, hd)
        return P(None, m(1, tp), None)
    if name == "wo":                             # (H, hd, D)
        return P(m(0, tp), None, None)
    if "moe" in path_keys and "shared" not in path_keys and \
            name in ("w_gate", "w_in"):          # (E, D, F)
        if _div(shape[0], mesh_shape, tp):
            return P(tp, None, None)             # expert parallel
        return P(None, None, m(2, tp))           # TP fallback (qwen2)
    if "moe" in path_keys and "shared" not in path_keys and \
            name == "w_out":                     # (E, F, D)
        if _div(shape[0], mesh_shape, tp):
            return P(tp, None, None)
        return P(None, m(1, tp), None)
    if name == "router":
        return P(None, None)
    if name in ("w_gate", "w_in", "w_ck", "w_cr", "w_r", "w_k", "w_v"):
        # (D, F)-like: shard the hidden/output dim
        return P(None, m(1, tp)) if ndim == 2 else P(*([None] * ndim))
    if name in ("w_out", "w_cv", "w_o"):         # (F, D)-like
        return P(m(0, tp), None) if ndim == 2 else P(*([None] * ndim))
    if name == "w_xdt":                          # mamba (di, rank)
        return P(m(0, tp), None)
    # mamba
    if name == "conv_w":                         # (k, di)
        return P(None, m(1, tp))
    if name in ("conv_b", "dt_bias", "D", "decay", "bonus"):
        return P(m(0, tp)) if ndim == 1 else P(*([None] * ndim))
    if name in ("w_B", "w_C", "A_log"):          # (di, N)
        return P(m(0, tp), None)
    if name == "w_dt":                           # (rank, di)
        return P(None, m(1, tp))
    if name == "w_dd1":                          # (D, lora)
        return P(None, None)
    if name == "w_dd2":
        return P(None, None)
    return P(*([None] * ndim))                   # norms, mixes, scalars


def strategy_for(arch: ArchConfig, mesh: jax.sharding.Mesh,
                 global_batch: int = 0):
    """(tp_axis, dp_axes) for an arch on a mesh.

    Attention-free archs whose head count doesn't divide the model axis
    (rwkv6: 40 heads vs 16) get NO tensor parallelism: every sharding of the
    WKV head dim is either uneven or needs a full reshard, so the right
    layout is pure data parallelism over ALL axes (weights FSDP-gathered
    per layer).  Everything else: TP over `model`, DP over pod+data."""
    import os
    all_axes = tuple(mesh.axis_names)
    dp_default = tuple(a for a in all_axes if a != "model")
    if os.environ.get("REPRO_SSM_TP", "0") == "1":
        return "model", dp_default
    if arch.family == "ssm":
        # fold `model` into DP: pick the largest axis combination that the
        # batch divides (multi-pod: 256 % 512 != 0, but 256 % ("data",
        # "model")=256 == 0 — replicate over "pod" rather than wasting the
        # model axis)
        candidates = [all_axes,
                      tuple(a for a in all_axes if a != "pod"),
                      dp_default, (dp_default[-1],)]
        for cand in candidates:
            size = 1
            for a in cand:
                size *= mesh.shape[a]
            if global_batch and global_batch % size == 0:
                return None, cand
        return None, dp_default
    return "model", dp_default


def param_pspecs(model: Model, mesh: jax.sharding.Mesh,
                 tp="model", fsdp="data") -> Any:
    """PartitionSpec tree matching model.init_abstract().

    fsdp: additionally shard the first remaining divisible dim of each >=2D
    weight over the data axis (ZeRO-3 / FSDP: GSPMD all-gathers weights per
    scan iteration, so per-chip parameter memory drops by the data-axis size
    — required to fit the 123B/398B archs on 16 GB chips)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    abstract = model.init_abstract()

    def one(path, leaf):
        keys = tuple(getattr(k, "key", str(k)) for k in path)
        stacked = "blocks" in keys
        shape = leaf.shape[1:] if stacked else leaf.shape
        spec = _spec_for(keys, shape, mesh_shape, tp)
        if fsdp is not None and len(shape) >= 2:
            entries = list(spec) + [None] * (len(shape) - len(spec))
            for i, (e, n) in enumerate(zip(entries, shape)):
                if e is None and n % mesh_shape[fsdp] == 0 and                         n >= mesh_shape[fsdp]:
                    entries[i] = fsdp
                    break
            spec = P(*entries)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, abstract)


def opt_pspecs(param_specs: Any, abstract_params: Any,
               mesh: jax.sharding.Mesh, zero1: bool = True,
               dp="data") -> Any:
    """Moment specs: same as params, plus ZeRO-1 sharding of the first
    divisible unsharded dim over the data axis."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(spec: P, leaf):
        if not zero1:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        flat = [e for ent in entries if ent is not None
                for e in (ent if isinstance(ent, tuple) else (ent,))]
        if dp in flat:        # already data-sharded (FSDP params)
            return P(*entries)
        for i, (s, n) in enumerate(zip(entries, leaf.shape)):
            if s is None and n % mesh_shape[dp] == 0 and n >= mesh_shape[dp]:
                entries[i] = dp
                break
        return P(*entries)

    return jax.tree_util.tree_map(one, param_specs, abstract_params)


def batch_pspecs(model: Model, shape: ShapeSpec, mesh: jax.sharding.Mesh,
                 dp=("data",), tp="model") -> Any:
    """Input specs for a cell; dp is a tuple of data-parallel axis names."""
    a = model.arch
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dpa = dp if len(dp) > 1 else dp[0]
    if not _div(shape.global_batch, mesh_shape, dpa):
        dpa = dp[0] if _div(shape.global_batch, mesh_shape, dp[0]) else None
    if shape.kind in ("train", "prefill"):
        out = {"tokens": P(dpa, None)}
        if a.frontend == "vlm":
            out["patch_embeds"] = P(dpa, None, None)
        if a.frontend == "audio":
            out["frame_embeds"] = P(dpa, None, None)
        if shape.kind == "train":
            out["labels"] = P(dpa, None)
        return out

    # decode: shard cache batch over dp; sequence over tp (flash-decode).
    # long-context (batch 1): sequence over (dp, tp) combined.
    seq_axes = tp if shape.global_batch > 1 else tuple(dp) + (tp,)
    bat_axes = dpa if shape.global_batch > 1 else None

    def cache_spec(path, leaf):
        keys = tuple(getattr(k, "key", str(k)) for k in path)
        nd = len(leaf.shape)
        name = keys[-1] if isinstance(keys[-1], str) else ""
        if name in ("k", "v"):           # (n_super, B, T, Kv, hd)
            sa = seq_axes if _div(leaf.shape[2], mesh_shape, seq_axes) else None
            return P(None, bat_axes, sa, None, None)
        if name == "wkv":                # (n_super, B*H, K, K)
            return P(None, bat_axes, None, None)
        if name in ("tm_shift", "cm_shift"):   # (n_super, B, 1, D)
            return P(None, bat_axes, None, None)
        if nd == 4 and a.mamba is not None and                 leaf.shape[-1] == a.mamba.d_state:
            # mamba ssm state (n_super, B, di, N)
            di_ax = tp if _div(leaf.shape[2], mesh_shape, tp) else None
            return P(None, bat_axes, di_ax, None)
        if nd == 4:                      # mamba conv state (n_super,B,k,di)
            di_ax = tp if _div(leaf.shape[3], mesh_shape, tp) else None
            return P(None, bat_axes, None, di_ax)
        return P(*([None] * nd))

    model_cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cache_specs = jax.tree_util.tree_map_with_path(cache_spec, model_cache)
    return {"cache": cache_specs, "tokens": P(bat_axes, None), "pos": P()}
