"""Shared transformer layers: norms, RoPE, GQA attention (train/prefill and
cached decode, incl. gemma2 sliding-window + logit soft-cap, olmo
non-parametric LN), MLPs.

Conventions: activations (B, T, D); params are nested dicts of arrays;
attention weights are stored head-major so the `model` mesh axis shards the
head dimension (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ref as kref


# --- norms ---------------------------------------------------------------------
def rms_norm(x, w, eps=1e-6):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(v + eps) * (1.0 + w)).astype(x.dtype)


def nonparam_layer_norm(x, eps=1e-5):
    """OLMo: LayerNorm without any learnable parameters."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm(x, w, kind: str):
    if kind == "nonparam":
        return nonparam_layer_norm(x)
    return rms_norm(x, w)


# --- RoPE ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, positions: jax.Array):
    """positions (T,) -> (T, head_dim/2) cos/sin tables."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, T, H, hd); cos/sin (T, hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
                           ).astype(x.dtype)


# --- attention --------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 1e4
    window: Optional[int] = None      # sliding-window size (gemma2 local)
    softcap: Optional[float] = None   # logit soft-capping (gemma2)
    causal: bool = True               # False for encoder-only (hubert)
    pad_heads_to: Optional[int] = None  # pad H for TP divisibility (§Perf:
                                        # starcoder2's 24 heads vs 16-way
                                        # model axis -> pad activations to 32
                                        # so each device owns 2 heads instead
                                        # of computing all 24)


def attn_params(rng, d_model, cfg: AttnCfg, dtype=jnp.bfloat16):
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    sc = 1.0 / (d_model ** 0.5)
    return {
        "wq": (jax.random.normal(k1, (d_model, cfg.n_heads, hd)) * sc).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, cfg.n_kv, hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, cfg.n_kv, hd)) * sc).astype(dtype),
        "wo": (jax.random.normal(k4, (cfg.n_heads, hd, d_model)) * sc).astype(dtype),
    }


def _repeat_kv(k, n_heads):
    """(B, T, Kv, hd) -> (B, T, H, hd) by group replication."""
    B, T, Kv, hd = k.shape
    rep = n_heads // Kv
    return jnp.repeat(k, rep, axis=2)


def attention(p, x, cfg: AttnCfg, positions: jax.Array,
              head_sharding=None):
    """Full (train/prefill) attention. x (B, T, D) -> (B, T, D).

    Uses the custom-VJP flash path on a (B, H, T, d) layout: the head axis
    keeps its `model` sharding (no B*H merge) and the backward pass
    recomputes scores per block (O(T) activation memory)."""
    from .attention import flash_attention_xla
    B, T, D = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    qh = q.transpose(0, 2, 1, 3)          # (B, H, T, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    Hp = cfg.pad_heads_to
    if Hp is not None and Hp > cfg.n_heads:
        padw = [(0, 0), (0, Hp - cfg.n_heads), (0, 0), (0, 0)]
        qh = jnp.pad(qh, padw)
        kh = jnp.pad(kh, padw)
        vh = jnp.pad(vh, padw)
    if head_sharding is not None:
        qh = jax.lax.with_sharding_constraint(qh, head_sharding)
        kh = jax.lax.with_sharding_constraint(kh, head_sharding)
        vh = jax.lax.with_sharding_constraint(vh, head_sharding)
    out = flash_attention_xla(qh, kh, vh, cfg.causal, cfg.window,
                              cfg.softcap)
    if Hp is not None and Hp > cfg.n_heads:
        out = out[:, :cfg.n_heads]
    out = out.transpose(0, 2, 1, 3)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def decode_attention(p, x, cfg: AttnCfg, kv_cache, pos: jax.Array):
    """Single-token decode against a KV cache.

    x: (B, 1, D); kv_cache: dict(k, v: (B, Tmax, Kv, hd)); pos: scalar index.
    Returns (out (B, 1, D), new_cache).  The cache T axis may be sharded over
    the data axis for long-context cells (flash-decode combine happens via
    the masked online softmax below under GSPMD)."""
    B, _, D = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k_new = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v_new = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    posv = jnp.asarray([pos])
    cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, posv)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    # index dtypes must match even under x64-enabled test environments
    pos = jnp.asarray(pos, jnp.int32)
    z = jnp.zeros((), jnp.int32)
    kc = jax.lax.dynamic_update_slice(kv_cache["k"], k_new.astype(
        kv_cache["k"].dtype), (z, pos, z, z))
    vc = jax.lax.dynamic_update_slice(kv_cache["v"], v_new.astype(
        kv_cache["v"].dtype), (z, pos, z, z))
    Tmax = kc.shape[1]
    ids = jnp.arange(Tmax)
    valid = ids <= pos
    if cfg.window is not None:
        valid = valid & (ids > pos - cfg.window)
    # grouped-head attention: never materialise the repeated KV. The cache's
    # T axis may be sharded (long-context cells): the reductions over t below
    # become local-reduce + small all-reduce under GSPMD (flash-decode).
    rep = cfg.n_heads // cfg.n_kv
    qg = q[:, 0].reshape(B, cfg.n_kv, rep, cfg.head_dim).astype(jnp.float32)
    s = jnp.einsum("bgrk,btgk->bgrt", qg, kc.astype(jnp.float32)) / (
        cfg.head_dim ** 0.5)
    if cfg.softcap is not None:
        s = cfg.softcap * jnp.tanh(s / cfg.softcap)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrt,btgk->bgrk", pattn, vc.astype(jnp.float32))
    out = out.reshape(B, cfg.n_heads, cfg.head_dim)
    out = jnp.einsum("bhk,hkd->bd", out.astype(x.dtype), p["wo"])
    return out[:, None, :], {"k": kc, "v": vc}


# --- MLPs ------------------------------------------------------------------------
def mlp_params(rng, d_model, d_ff, act: str, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(rng, 3)
    sc_in = 1.0 / (d_model ** 0.5)
    sc_out = 1.0 / (d_ff ** 0.5)
    p = {"w_out": (jax.random.normal(k2, (d_ff, d_model)) * sc_out).astype(dtype)}
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(k1, (d_model, d_ff)) * sc_in).astype(dtype)
        p["w_in"] = (jax.random.normal(k3, (d_model, d_ff)) * sc_in).astype(dtype)
    else:
        p["w_in"] = (jax.random.normal(k1, (d_model, d_ff)) * sc_in).astype(dtype)
    return p


def mlp(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    else:
        h = jax.nn.gelu(x @ p["w_in"])
    return h @ p["w_out"]
