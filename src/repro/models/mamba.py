"""Mamba (S6) block for the jamba hybrid architecture.

Selective SSM with data-dependent (dt, B, C); the sequential scan over time
is the same independent-recurrences-in-lanes motif as the ocean model's
column solvers (channels ride in lanes, time is the sequential axis).
Training/prefill uses an associative-scan-free chunked lax.scan (O(T) memory);
decode keeps (conv_state, ssm_state) per layer — O(1) per token, which is why
jamba runs the long_500k cell that quadratic-attention models skip.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

_CHUNKED = os.environ.get("REPRO_MAMBA_CHUNKED", "1") == "1"


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model):
        return self.expand * d_model


def mamba_params(rng, d_model, cfg: MambaCfg, dtype=jnp.bfloat16):
    di = cfg.d_inner(d_model)
    ks = jax.random.split(rng, 7)
    sc = 1.0 / (d_model ** 0.5)
    dt_rank = max(d_model // 16, 1)
    return {
        "w_in": (jax.random.normal(ks[0], (d_model, 2 * di)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_xdt": (jax.random.normal(ks[2], (di, dt_rank)) * sc).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (dt_rank, di)) * 0.1).astype(dtype),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),  # softplus -> small dt
        "w_B": (jax.random.normal(ks[4], (di, cfg.d_state)) * sc).astype(dtype),
        "w_C": (jax.random.normal(ks[5], (di, cfg.d_state)) * sc).astype(dtype),
        "A_log": jnp.log(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32)
                         )[None, :].repeat(di, 0),      # (di, N)
        "D": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(ks[6], (di, d_model)) / (di ** 0.5)
                  ).astype(dtype),
    }


def _ssm_scan(u, dt, B, C, A, D, chunk: int = 32):
    """u, dt: (Bt, T, di); B, C: (Bt, T, N); A: (di, N); D: (di,).

    h_t = exp(dt*A) h_{t-1} + dt*B_t*u_t ; y_t = (C_t . h_t) + D*u_t

    Chunk-checkpointed recurrence (§Perf, jamba hillclimb): the naive form
    materialises dA/dBu as (Bt, T, di, N) tensors BEFORE the scan and stacks
    a per-token state residual for backward — 4 orders of magnitude of HBM
    traffic at 4k context.  Here the decay/input terms are built per step
    inside a jax.checkpoint'ed chunk, so backward stores one (Bt, di, N)
    state per T/chunk tokens and recomputes within chunks.  (mamba-1's
    per-(d,n) selective decay admits no exact chunk-parallel matmul form —
    that is mamba-2/SSD — so the recurrence stays sequential but bounded.)
    """
    Bt, T, di = u.shape
    N = A.shape[1]
    if not _CHUNKED:   # baseline: materialised dA/dBu + per-token scan
        dA = jnp.exp(dt[..., None] * A[None, None])
        dBu = (dt * u)[..., None] * B[:, :, None, :]
        def step0(h, xs):
            dA_t, dBu_t, C_t = xs
            h = dA_t * h + dBu_t
            return h, jnp.einsum("bdn,bn->bd", h, C_t)
        h0 = jnp.zeros((Bt, di, N), jnp.float32)
        _, ys = jax.lax.scan(step0, h0, (dA.swapaxes(0, 1),
                                         dBu.swapaxes(0, 1),
                                         C.swapaxes(0, 1)))
        return ys.swapaxes(0, 1) + D[None, None] * u
    c = min(chunk, T)
    assert T % c == 0
    nch = T // c

    def split(x):
        return x.reshape(Bt, nch, c, x.shape[-1]).swapaxes(0, 1)

    us, dts, Bs, Cs = split(u), split(dt), split(B), split(C)

    @jax.checkpoint
    def one_chunk(h, xs):
        uc, dtc, Bc, Cc = xs                              # (Bt, c, ...)

        def step(h, xs2):
            ut, dtt, Bt_, Ct = xs2                        # (Bt, di/N)
            dA = jnp.exp(dtt[..., None] * A[None])        # (Bt, di, N)
            h = dA * h + (dtt * ut)[..., None] * Bt_[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, Ct)
            return h, y

        h, ys = jax.lax.scan(
            step, h, (uc.swapaxes(0, 1), dtc.swapaxes(0, 1),
                      Bc.swapaxes(0, 1), Cc.swapaxes(0, 1)))
        return h, ys.swapaxes(0, 1)                       # (Bt, c, di)

    h0 = jnp.zeros((Bt, di, N), jnp.float32)
    _, ys = jax.lax.scan(one_chunk, h0, (us, dts, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(Bt, T, di)
    return y + D[None, None] * u


def mamba_apply(p, x, cfg: MambaCfg):
    """Train/prefill: x (B, T, D) -> (B, T, D)."""
    B, T, D = x.shape
    di = cfg.d_inner(D)
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B, T, di) each
    # causal depthwise conv
    pad = jnp.zeros((B, cfg.d_conv - 1, di), xi.dtype)
    xpad = jnp.concatenate([pad, xi], axis=1)
    conv = sum(xpad[:, k:k + T, :] * p["conv_w"][k][None, None]
               for k in range(cfg.d_conv)) + p["conv_b"]
    u = jax.nn.silu(conv).astype(jnp.float32)
    dt = jax.nn.softplus((u @ p["w_xdt"].astype(jnp.float32))
                         @ p["w_dt"].astype(jnp.float32) + p["dt_bias"])
    Bm = u @ p["w_B"].astype(jnp.float32)                 # (B, T, N)
    Cm = u @ p["w_C"].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    y = _ssm_scan(u, dt, Bm, Cm, A, p["D"])
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out


def mamba_decode(p, x, state, cfg: MambaCfg):
    """Single-token decode. x (B, 1, D); state = (conv_state (B, d_conv-1, di),
    ssm_state (B, di, N)). Returns (out, new_state)."""
    B, _, D = x.shape
    xz = x[:, 0] @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B, di)
    conv_state, h = state
    xc = jnp.concatenate([conv_state, xi[:, None]], axis=1)  # (B, d_conv, di)
    conv = (xc * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
    u = jax.nn.silu(conv).astype(jnp.float32)             # (B, di)
    dt = jax.nn.softplus((u @ p["w_xdt"].astype(jnp.float32))
                         @ p["w_dt"].astype(jnp.float32) + p["dt_bias"])
    Bm = u @ p["w_B"].astype(jnp.float32)                 # (B, N)
    Cm = u @ p["w_C"].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A[None])                 # (B, di, N)
    h = dA * h + (dt * u)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + p["D"][None] * u
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out[:, None], (xc[:, 1:], h)


def init_mamba_state(batch, d_model, cfg: MambaCfg, dtype=jnp.bfloat16):
    di = cfg.d_inner(d_model)
    return (jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
            jnp.zeros((batch, di, cfg.d_state), jnp.float32))
