"""Unified LM model: builds any assigned architecture from its ArchConfig.

Layer stacks are expressed as a *super-block program*: a static list of
sub-layer descriptors (mixer kind, FFN kind, attention window) that repeats
n_super = L / len(program) times.  The stack is one lax.scan over stacked
per-super-block parameters with the program unrolled inside the body — so

  * compile time / HLO size stay O(1) in depth,
  * heterogeneous patterns (gemma2 local/global alternation, jamba's
    1-attention-per-8 + MoE-every-2, phi/qwen all-MoE) cost exactly their
    own FLOPs (no masked double-compute — the roofline useful-FLOPs ratio
    stays honest),
  * decode uses the same program with per-sub-layer caches.

Entry points per arch: loss/train forward (train_4k), prefill
(prefill_32k; encode for encoder-only), decode_step (decode_32k/long_500k).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import layers, mamba, moe, rwkv
from .layers import AttnCfg

Params = Dict[str, Any]
_BIG_WINDOW = None  # global attention


@dataclasses.dataclass(frozen=True)
class SubLayer:
    mixer: str                     # attn | mamba | rwkv
    ffn: str                       # dense | moe | none (rwkv has channel-mix)
    window: Optional[int] = None   # static sliding window for this sub-layer


def block_program(arch: ArchConfig) -> List[SubLayer]:
    """The static per-super-block layer pattern of an architecture."""
    if arch.family == "ssm":
        return [SubLayer("rwkv", "none")]
    if arch.attn_period > 0:       # jamba: attn at the middle of each block,
        prog = []                  # MoE on odd sub-layers
        for i in range(arch.attn_period):
            mixer = "attn" if i == arch.attn_period // 2 else "mamba"
            ffn = "moe" if (arch.moe is not None and
                            i % arch.moe_period == arch.moe_period - 1) \
                else "dense"
            prog.append(SubLayer(mixer, ffn))
        return prog
    if arch.alt_local_global:      # gemma2: local (windowed) then global
        return [SubLayer("attn", "dense", window=arch.window),
                SubLayer("attn", "dense", window=None)]
    if arch.moe is not None:
        if arch.moe_period > 1:
            return ([SubLayer("attn", "dense")] * (arch.moe_period - 1)
                    + [SubLayer("attn", "moe")])
        return [SubLayer("attn", "moe")]
    return [SubLayer("attn", "dense", window=arch.window)]


def _attn_cfg(arch: ArchConfig, window, pad_heads_to=None) -> AttnCfg:
    return AttnCfg(n_heads=arch.n_heads, n_kv=arch.n_kv, head_dim=arch.hd,
                   rope_theta=arch.rope_theta, window=window,
                   softcap=arch.softcap_attn, causal=arch.causal,
                   pad_heads_to=pad_heads_to)


class Model:
    """Architecture-parameterised model (pure functions + config)."""

    def __init__(self, arch: ArchConfig, dtype=jnp.bfloat16):
        self.arch = arch
        self.dtype = dtype
        # optional NamedShardings set by the launcher: logits keeps (B,T,V)
        # vocab-sharded through the loss; act pins the residual stream to
        # batch-sharding at block boundaries (without this, FSDP weight
        # sharding on contracted dims makes GSPMD replicate the batch)
        self.logits_sharding = None
        self.act_sharding = None        # residual stream BETWEEN blocks
        self.act_inner_sharding = None  # WITHIN a block (Megatron-SP: the
                                        # carry stays seq-sharded, compute
                                        # runs on the gathered sequence)
        self.head_sharding = None   # (B*H, T, K) reshard for rwkv wkv
        # two-level remat: scan over groups of super-blocks with the whole
        # group checkpointed -> per-layer residual stacks never materialise
        # (only n_groups carries + one transient group in backward)
        self.remat_groups = None
        self.moe_hidden_sharding = None  # decode: pin (B,T,E,F) dispatch
        self.pad_heads_to = None         # TP head padding (see AttnCfg)
        self.attn_head_sharding = None   # (B, H, T, d) pin for padded heads
        self.program = block_program(arch)
        assert arch.n_layers % len(self.program) == 0, (
            arch.name, arch.n_layers, len(self.program))
        self.n_super = arch.n_layers // len(self.program)

    # ------------------------------------------------------------------ init
    def _sub_init(self, rng, sub: SubLayer) -> Params:
        a = self.arch
        D, F = a.d_model, a.d_ff
        ks = jax.random.split(rng, 4)
        p: Params = {"ln1": jnp.zeros((D,), self.dtype),
                     "ln2": jnp.zeros((D,), self.dtype)}
        if sub.mixer == "rwkv":
            p["rwkv"] = rwkv.rwkv_params(ks[0], D, F, a.rwkv, self.dtype)
            return p
        if sub.mixer == "attn":
            p["attn"] = layers.attn_params(ks[0], D, _attn_cfg(a, None),
                                           self.dtype)
        else:
            p["mamba"] = mamba.mamba_params(ks[0], D, a.mamba, self.dtype)
        if sub.ffn == "moe":
            p["moe"] = moe.moe_params(ks[1], D, a.moe, a.act, self.dtype)
        elif sub.ffn == "dense":
            p["mlp"] = layers.mlp_params(ks[1], D, F, a.act, self.dtype)
        return p

    def init(self, rng) -> Params:
        a = self.arch
        k_emb, k_head, k_layers, k_fr = jax.random.split(rng, 4)
        D = a.d_model
        p: Params = {
            "embed": (jax.random.normal(k_emb, (a.vocab, D)) * 0.02
                      ).astype(self.dtype),
            "final_norm": jnp.zeros((D,), self.dtype),
        }
        if not a.tie_embeddings:
            p["head"] = (jax.random.normal(k_head, (D, a.vocab)) * 0.02
                         ).astype(self.dtype)
        blocks = {}
        keys = jax.random.split(k_layers, len(self.program))
        for i, sub in enumerate(self.program):
            sks = jax.random.split(keys[i], self.n_super)
            blocks[f"sub{i}"] = jax.vmap(
                functools.partial(self._sub_init, sub=sub))(sks)
        p["blocks"] = blocks
        if a.frontend == "vlm":
            p["vlm_proj"] = (jax.random.normal(k_fr, (D, D)) / (D ** 0.5)
                             ).astype(self.dtype)
        if a.frontend == "audio":
            p["audio_proj"] = (jax.random.normal(k_fr, (D, D)) / (D ** 0.5)
                               ).astype(self.dtype)
        return p

    def init_abstract(self) -> Params:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # -------------------------------------------------------------- sublayer
    def _apply_sub(self, p, x, sub: SubLayer, positions):
        a = self.arch
        aux = jnp.zeros((), jnp.float32)
        h = layers.norm(x, p["ln1"], a.norm)
        if sub.mixer == "rwkv":
            tm, _ = rwkv.time_mix(p["rwkv"], h, a.rwkv,
                                  head_sharding=self.head_sharding)
            x = x + tm
            cm, _ = rwkv.channel_mix(p["rwkv"],
                                     layers.norm(x, p["ln2"], a.norm))
            return x + cm, aux
        if sub.mixer == "attn":
            mix = layers.attention(
                p["attn"], h,
                _attn_cfg(a, sub.window, pad_heads_to=self.pad_heads_to),
                positions, head_sharding=self.attn_head_sharding)
        else:
            mix = mamba.mamba_apply(p["mamba"], h, a.mamba)
        x = x + mix
        h2 = layers.norm(x, p["ln2"], a.norm)
        if sub.ffn == "moe":
            ffn, aux = moe.moe_apply(p["moe"], h2, a.moe)
        else:
            ffn = layers.mlp(p["mlp"], h2, a.act)
        return x + ffn, aux

    def _embed(self, params, batch):
        a = self.arch
        if a.frontend == "audio":
            return batch["frame_embeds"].astype(self.dtype) @ params["audio_proj"]
        x = params["embed"][batch["tokens"]]
        if a.name.startswith("gemma"):
            x = x * jnp.asarray(a.d_model ** 0.5, x.dtype)
        if a.frontend == "vlm":
            pe = batch["patch_embeds"].astype(self.dtype) @ params["vlm_proj"]
            x = jnp.concatenate([pe, x[:, a.n_patches:]], axis=1)
        return x

    # ---------------------------------------------------------------- forward
    def _pin(self, x):
        if self.act_sharding is not None and x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    def _pin_inner(self, x):
        if self.act_inner_sharding is not None and x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, self.act_inner_sharding)
        return x

    def forward(self, params, batch):
        """Full-sequence forward -> (logits (B, T, V), aux_loss)."""
        a = self.arch
        x = self._pin(self._embed(params, batch))
        positions = jnp.arange(x.shape[1])

        def body(xc, blk):
            aux = jnp.zeros((), jnp.float32)
            for i, sub in enumerate(self.program):
                def fn(p_, x_, sub=sub):
                    # gather the sequence at block entry (Megatron-SP),
                    # compute on the full sequence, let the trailing pin
                    # reduce-scatter the output back to the sharded carry
                    x_ = self._pin_inner(x_)
                    return self._apply_sub(p_, x_, sub, positions)
                if a.remat:
                    fn = jax.checkpoint(
                        fn, policy=jax.checkpoint_policies.nothing_saveable)
                xc, a_ = fn(blk[f"sub{i}"], xc)
                xc = self._pin(xc)
                aux = aux + a_
            return xc, aux

        groups = self.remat_groups
        if groups and groups > 1 and self.n_super % groups == 0 and a.remat:
            gs = self.n_super // groups
            blocks_g = jax.tree_util.tree_map(
                lambda t: t.reshape((groups, gs) + t.shape[1:]),
                params["blocks"])

            @jax.checkpoint
            def group_body(xc, blkg):
                return jax.lax.scan(body, xc, blkg)

            x, auxes = jax.lax.scan(group_body, x, blocks_g)
        else:
            x, auxes = jax.lax.scan(body, x, params["blocks"])
        x = layers.norm(x, params["final_norm"], a.norm)
        head = params["embed"].T if a.tie_embeddings else params["head"]
        logits = x @ head
        if a.softcap_logits is not None:
            logits = a.softcap_logits * jnp.tanh(logits / a.softcap_logits)
        return logits, auxes.sum()

    def loss(self, params, batch):
        a = self.arch
        logits, aux = self.forward(params, batch)
        if self.logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, self.logits_sharding)
        labels = batch["labels"]
        if a.causal and not a.encoder_only:
            logits = logits[:, :-1]
            labels = labels[:, 1:]
        # vocab-sharding-friendly cross entropy: logsumexp + one-hot gather
        # (take_along_axis over a sharded V would force an all-gather of the
        # full logits)
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        sel = labels[..., None] == jnp.arange(a.vocab)[None, None, :]
        gold = jnp.sum(jnp.where(sel, lf, 0.0), axis=-1)
        nll = lse - gold
        return nll.mean() + aux

    def prefill(self, params, batch):
        """Full-sequence forward returning last-token logits (B, V)."""
        logits, _ = self.forward(params, batch)
        return logits[:, -1]

    # ---------------------------------------------------------------- decode
    def _sub_cache(self, batch: int, max_len: int, sub: SubLayer):
        a = self.arch
        if sub.mixer == "rwkv":
            return rwkv.init_rwkv_state(batch, a.d_model, a.rwkv, self.dtype)
        if sub.mixer == "mamba":
            return mamba.init_mamba_state(batch, a.d_model, a.mamba,
                                          self.dtype)
        return {"k": jnp.zeros((batch, max_len, a.n_kv, a.hd), self.dtype),
                "v": jnp.zeros((batch, max_len, a.n_kv, a.hd), self.dtype)}

    def init_cache(self, batch: int, max_len: int):
        """Stacked decode state: {sub_i: (n_super, ...)}."""
        out = {}
        for i, sub in enumerate(self.program):
            c = self._sub_cache(batch, max_len, sub)
            out[f"sub{i}"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x[None], (self.n_super,) + x.shape), c)
        return out

    def _decode_sub(self, p, x, cch, sub: SubLayer, pos):
        a = self.arch
        h = layers.norm(x, p["ln1"], a.norm)
        if sub.mixer == "rwkv":
            tm, (tshift, wkv_s) = rwkv.time_mix(
                p["rwkv"], h, a.rwkv, shift_state=cch["tm_shift"],
                wkv_state=cch["wkv"])
            x = x + tm
            cm, cshift = rwkv.channel_mix(
                p["rwkv"], layers.norm(x, p["ln2"], a.norm),
                shift_state=cch["cm_shift"])
            return x + cm, {"tm_shift": tshift, "cm_shift": cshift,
                            "wkv": wkv_s}
        if sub.mixer == "attn":
            mix, new_c = layers.decode_attention(
                p["attn"], h, _attn_cfg(a, sub.window), cch, pos)
        else:
            mix, new_c = mamba.mamba_decode(p["mamba"], h, cch, a.mamba)
        x = x + mix
        h2 = layers.norm(x, p["ln2"], a.norm)
        if sub.ffn == "moe":
            ffn, _ = moe.moe_apply(p["moe"], h2, a.moe,
                                   hidden_sharding=self.moe_hidden_sharding)
        else:
            ffn = layers.mlp(p["mlp"], h2, a.act)
        return x + ffn, new_c

    def decode_step(self, params, cache, tokens, pos):
        """tokens (B, 1); pos scalar int32 -> (logits (B, V), new cache)."""
        a = self.arch
        x = params["embed"][tokens]
        if a.name.startswith("gemma"):
            x = x * jnp.asarray(a.d_model ** 0.5, x.dtype)

        def body(xc, inp):
            blk, cch = inp
            new_cs = {}
            for i, sub in enumerate(self.program):
                xc, nc = self._decode_sub(blk[f"sub{i}"], xc,
                                          cch[f"sub{i}"], sub, pos)
                new_cs[f"sub{i}"] = nc
            return xc, new_cs

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = layers.norm(x, params["final_norm"], a.norm)
        head = params["embed"].T if a.tie_embeddings else params["head"]
        logits = x[:, 0] @ head
        if a.softcap_logits is not None:
            logits = a.softcap_logits * jnp.tanh(logits / a.softcap_logits)
        return logits, new_cache

    # ----------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeSpec):
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        a = self.arch
        B, T = shape.global_batch, shape.seq_len
        f = jnp.bfloat16 if self.dtype == jnp.bfloat16 else jnp.float32
        if shape.kind in ("train", "prefill"):
            batch = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
            if a.frontend == "vlm":
                batch["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, a.n_patches, a.d_model), f)
            if a.frontend == "audio":
                batch["frame_embeds"] = jax.ShapeDtypeStruct(
                    (B, T, a.d_model), f)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
            return batch
        cache = jax.eval_shape(lambda: self.init_cache(B, T))
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def count_params(model: Model) -> Tuple[int, int]:
    """(total, active) parameter counts from the abstract tree.

    Active scales routed-expert weights by top_k / n_experts (MoE cells
    report MODEL_FLOPS = 6 * N_active * D)."""
    import numpy as np
    abstract = model.init_abstract()
    total = 0
    active = 0.0
    a = model.arch
    for path, leaf in jax.tree_util.tree_flatten_with_path(abstract)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(k, "key", str(k)) for k in path]
        in_moe = "moe" in keys
        is_shared = "shared" in keys
        if in_moe and not is_shared and any(
                k in ("w_gate", "w_in", "w_out") for k in keys):
            active += n * (a.moe.top_k / a.moe.n_experts)
        else:
            active += n
    return total, int(active)
