"""Flash attention in plain XLA with a custom VJP (the dry-run/CPU analogue
of the Pallas kernel; same math, O(T) memory in BOTH directions).

Without this, differentiating the blocked-softmax scans saves the per-block
probability tensors for backward — (nq, nk, B, H, qb, ck) f32 = tens of GiB
per device at 4k context (observed 16 GiB on olmo-1b train_4k).  The custom
VJP stores only (out, m, l) row statistics and recomputes scores per block in
the backward sweep, exactly like the TPU kernel's bwd pass.

Layout: (B, H, T, d) with NO B*H merge — the head axis keeps its `model`
sharding through every einsum (merging B with a sharded H forced an
all-gather of the heads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def _mask(q0, k0, qb, ck, causal, window):
    q_ids = q0 + jnp.arange(qb)[:, None]
    k_ids = k0 + jnp.arange(ck)[None, :]
    m = jnp.ones((qb, ck), bool)
    if causal:
        m = m & (k_ids <= q_ids)
    if window is not None:
        m = m & (k_ids > q_ids - window)
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_xla(q, k, v, causal=True, window=None, softcap=None,
                        q_block=512, k_block=1024):
    out, _, _ = _forward(q, k, v, causal, window, softcap, q_block, k_block)
    return out


def _forward(q, k, v, causal, window, softcap, q_block, k_block):
    B, H, Tq, d = q.shape
    Tk = k.shape[2]
    qb = min(q_block, Tq)
    ck = min(k_block, Tk)
    nq, nk = Tq // qb, Tk // ck
    scale = 1.0 / (d ** 0.5)
    ks = k.reshape(B, H, nk, ck, d)
    vs = v.reshape(B, H, nk, ck, d)

    def one_q(args):
        qc, iq = args                                    # (B, H, qb, d)
        qcf = qc.astype(jnp.float32) * scale

        def body(carry, j):
            m, l, acc = carry
            kc = jax.lax.dynamic_index_in_dim(ks, j, 2, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, j, 2, keepdims=False)
            s = jnp.einsum("bhqd,bhkd->bhqk", qcf, kc.astype(jnp.float32))
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            msk = _mask(iq * qb, j * ck, qb, ck, causal, window)
            s = jnp.where(msk[None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(-1, keepdims=True)
            acc = alpha * acc + jnp.einsum("bhqk,bhkd->bhqd", p,
                                           vc.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((B, H, qb, 1), NEG, jnp.float32),
                jnp.zeros((B, H, qb, 1), jnp.float32),
                jnp.zeros((B, H, qb, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30), m, l

    qs = q.reshape(B, H, nq, qb, d).transpose(2, 0, 1, 3, 4)
    out, m, l = jax.lax.map(one_q, (qs, jnp.arange(nq)))
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, Tq, d).astype(q.dtype)
    m = m.transpose(1, 2, 0, 3, 4).reshape(B, H, Tq, 1)
    l = l.transpose(1, 2, 0, 3, 4).reshape(B, H, Tq, 1)
    return out, m, l


def _fwd_rule(q, k, v, causal, window, softcap, q_block, k_block):
    out, m, l = _forward(q, k, v, causal, window, softcap, q_block, k_block)
    return out, (q, k, v, out, m, l)


def _bwd_rule(causal, window, softcap, q_block, k_block, res, dout):
    """Two-pass flash backward: q-outer loop for dq, kv-outer loop for dk/dv
    (recomputing scores in each — no stacked (nq x nk) probability tensors;
    peak extra memory is one (B, H, qb, ck) block)."""
    q, k, v, out, m, l = res
    B, H, Tq, d = q.shape
    Tk = k.shape[2]
    qb = min(q_block, Tq)
    ck = min(k_block, Tk)
    nq, nk = Tq // qb, Tk // ck
    scale = 1.0 / (d ** 0.5)
    Dsum = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1, keepdims=True)                # (B,H,Tq,1)
    ks_ = k.reshape(B, H, nk, ck, d)
    vs_ = v.reshape(B, H, nk, ck, d)
    qs_ = q.reshape(B, H, nq, qb, d)
    do_ = dout.reshape(B, H, nq, qb, d)
    ms_ = m.reshape(B, H, nq, qb, 1)
    ls_ = l.reshape(B, H, nq, qb, 1)
    Ds_ = Dsum.reshape(B, H, nq, qb, 1)

    def block_grads(iq, j, qc, dc, mc, lc, Dc):
        """Recompute p for block (iq, j); return (ds, p) pieces."""
        qcf = qc.astype(jnp.float32) * scale
        kc = jax.lax.dynamic_index_in_dim(ks_, j, 2, keepdims=False
                                          ).astype(jnp.float32)
        vc = jax.lax.dynamic_index_in_dim(vs_, j, 2, keepdims=False
                                          ).astype(jnp.float32)
        s_raw = jnp.einsum("bhqd,bhkd->bhqk", qcf, kc)
        if softcap is not None:
            t = jnp.tanh(s_raw / softcap)
            s = softcap * t
        else:
            t = None
            s = s_raw
        msk = _mask(iq * qb, j * ck, qb, ck, causal, window)
        s = jnp.where(msk[None, None], s, NEG)
        p = jnp.exp(s - mc) / jnp.maximum(lc, 1e-30)      # (B,H,qb,ck)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dc.astype(jnp.float32), vc)
        ds = p * (dp - Dc)
        if softcap is not None:
            ds = ds * (1.0 - t * t)
        ds = jnp.where(msk[None, None], ds, 0.0)
        return ds, p, kc, qcf

    # pass 1: dq, q-blocks outer
    def one_q(args):
        qc, dc, mc, lc, Dc, iq = args

        def body(dq, j):
            ds, _, kc, _ = block_grads(iq, j, qc, dc, mc, lc, Dc)
            return dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kc) * scale, None

        dq, _ = jax.lax.scan(body, jnp.zeros((B, H, qb, d), jnp.float32),
                             jnp.arange(nk))
        return dq

    qs_t = qs_.transpose(2, 0, 1, 3, 4)
    do_t = do_.transpose(2, 0, 1, 3, 4)
    ms_t = ms_.transpose(2, 0, 1, 3, 4)
    ls_t = ls_.transpose(2, 0, 1, 3, 4)
    Ds_t = Ds_.transpose(2, 0, 1, 3, 4)
    dq = jax.lax.map(one_q, (qs_t, do_t, ms_t, ls_t, Ds_t, jnp.arange(nq)))
    dq = dq.transpose(1, 2, 0, 3, 4).reshape(B, H, Tq, d).astype(q.dtype)

    # pass 2: dk/dv, kv-blocks outer, q inner (accumulated in carry)
    def one_k(j):
        def body(carry, iq):
            dk_j, dv_j = carry
            qc = jax.lax.dynamic_index_in_dim(qs_, iq, 2, keepdims=False)
            dc = jax.lax.dynamic_index_in_dim(do_, iq, 2, keepdims=False)
            mc = jax.lax.dynamic_index_in_dim(ms_, iq, 2, keepdims=False)
            lc = jax.lax.dynamic_index_in_dim(ls_, iq, 2, keepdims=False)
            Dc = jax.lax.dynamic_index_in_dim(Ds_, iq, 2, keepdims=False)
            ds, p, _, qcf = block_grads(iq, j, qc, dc, mc, lc, Dc)
            dk_j = dk_j + jnp.einsum("bhqk,bhqd->bhkd", ds, qcf)
            dv_j = dv_j + jnp.einsum("bhqk,bhqd->bhkd", p,
                                     dc.astype(jnp.float32))
            return (dk_j, dv_j), None

        z = jnp.zeros((B, H, ck, d), jnp.float32)
        (dk_j, dv_j), _ = jax.lax.scan(body, (z, z), jnp.arange(nq))
        return dk_j, dv_j

    dks, dvs = jax.lax.map(one_k, jnp.arange(nk))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, H, Tk, d).astype(k.dtype)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, H, Tk, d).astype(v.dtype)
    return dq, dk, dv


flash_attention_xla.defvjp(_fwd_rule, _bwd_rule)
