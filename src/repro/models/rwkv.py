"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

The WKV recurrence runs through kernels/ops.wkv6 (Pallas on TPU, scan ref on
CPU).  Decode carries (token_shift, wkv_state) — O(1) per token, so rwkv6-3b
runs the long_500k cell natively.

The data-dependent decay follows the Finch structure (low-rank modulation of
a learned per-channel decay); the ddlerp token-shift interpolation is reduced
to a single learned mix per projection (documented simplification — the
computational shape, which is what the roofline sees, is identical).
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

# A/B knob for the §Perf hillclimb: 0 = paper-baseline per-token scan
_USE_CHUNKED = os.environ.get("REPRO_WKV_CHUNKED", "1") == "1"

from ..kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class RwkvCfg:
    head_dim: int = 64

    def n_heads(self, d_model):
        return d_model // self.head_dim


def rwkv_params(rng, d_model, d_ff, cfg: RwkvCfg, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 10)
    sc = 1.0 / (d_model ** 0.5)
    H = cfg.n_heads(d_model)
    lora = max(d_model // 16, 32)
    return {
        "mix_r": jnp.full((d_model,), 0.5, dtype),
        "mix_k": jnp.full((d_model,), 0.5, dtype),
        "mix_v": jnp.full((d_model,), 0.5, dtype),
        "mix_w": jnp.full((d_model,), 0.5, dtype),
        "w_r": (jax.random.normal(ks[0], (d_model, d_model)) * sc).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d_model, d_model)) * sc).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d_model, d_model)) * sc).astype(dtype),
        "w_o": (jax.random.normal(ks[3], (d_model, d_model)) * sc).astype(dtype),
        # data-dependent decay: w_t = exp(-exp(decay + lora(x)))
        "decay": jnp.full((d_model,), -1.0, jnp.float32),
        "w_dd1": (jax.random.normal(ks[4], (d_model, lora)) * sc).astype(dtype),
        "w_dd2": (jax.random.normal(ks[5], (lora, d_model)) * 0.1).astype(dtype),
        "bonus": (0.1 * jax.random.normal(ks[6], (d_model,))).astype(jnp.float32),
        "ln_x": jnp.zeros((d_model,), dtype),
        # channel mix
        "cmix_k": jnp.full((d_model,), 0.5, dtype),
        "w_ck": (jax.random.normal(ks[7], (d_model, d_ff)) * sc).astype(dtype),
        "w_cv": (jax.random.normal(ks[8], (d_ff, d_model)) / (d_ff ** 0.5)
                 ).astype(dtype),
        "w_cr": (jax.random.normal(ks[9], (d_model, d_model)) * sc).astype(dtype),
    }


def _token_shift(x, last=None):
    """Shift by one token: (B, T, D) -> previous token's activation."""
    B, T, D = x.shape
    prev = jnp.zeros((B, 1, D), x.dtype) if last is None else last
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def time_mix(p, x, cfg: RwkvCfg, shift_state=None, wkv_state=None,
             head_sharding=None):
    """x (B, T, D) -> (out, (new_shift, new_wkv)); states enable decode.

    head_sharding: optional NamedSharding for the (B*H, T, K) head tensors.
    RWKV's 40 heads don't divide the 16-way model axis, so without an
    explicit reshard GSPMD all-gathers D and every device computes ALL heads
    (16x redundant WKV).  Pinning the merged B*H dim to (data, model) — 10240
    % 256 == 0 — runs the WKV fully sharded at the cost of two reshards per
    layer (§Perf iteration 2 of the rwkv6 hillclimb)."""
    B, T, D = x.shape
    H = cfg.n_heads(D)
    K = cfg.head_dim
    xs = _token_shift(x, shift_state)
    def mix(m):
        return x * m + xs * (1 - m)
    r = mix(p["mix_r"]) @ p["w_r"]
    k = mix(p["mix_k"]) @ p["w_k"]
    v = mix(p["mix_v"]) @ p["w_v"]
    xw = mix(p["mix_w"]).astype(jnp.float32)
    dd = (xw @ p["w_dd1"].astype(jnp.float32)) @ p["w_dd2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["decay"][None, None] + dd))    # (B, T, D) in (0,1)

    def heads(z):
        zh = z.reshape(B, T, H, K).transpose(0, 2, 1, 3).reshape(B * H, T, K)
        if head_sharding is not None:
            zh = jax.lax.with_sharding_constraint(zh, head_sharding)
        return zh
    u = p["bonus"].reshape(H, K)

    if wkv_state is None:
        if _USE_CHUNKED:
            # train/prefill: chunkwise-parallel WKV (see wkv_chunked)
            uh = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
            out, _ = wkv_chunked(heads(r), heads(k), heads(v),
                                 heads(w.astype(x.dtype)), uh,
                                 jnp.zeros((B * H, K, K), jnp.float32),
                                 chunk=min(64, T))
        else:  # baseline: sequential per-token recurrence
            out, _ = _wkv_with_state(
                heads(r).astype(jnp.float32), heads(k).astype(jnp.float32),
                heads(v).astype(jnp.float32),
                heads(w.astype(jnp.float32)), u,
                jnp.zeros((B * H, K, K), jnp.float32))
        new_wkv = None
    else:
        out, new_wkv = _wkv_with_state(
            heads(r).astype(jnp.float32), heads(k).astype(jnp.float32),
            heads(v).astype(jnp.float32), heads(w.astype(jnp.float32)), u,
            wkv_state)
    out = out.reshape(B, H, T, K).transpose(0, 2, 1, 3).reshape(B, T, D)
    # group-norm-ish scale then output proj
    out = out * (1.0 + p["ln_x"])
    out = out.astype(x.dtype) @ p["w_o"]
    return out, (x[:, -1:], new_wkv)


def wkv_chunked(r, k, v, w, u, S0, chunk: int = 64):
    """Chunkwise-parallel WKV6 (beyond-paper §Perf optimisation).

    The per-token scan costs T sequential state updates — on TPU/XLA each is
    a fusion boundary that round-trips the (BH, K, V) state through HBM and
    stacks per-token residuals for backward (the rwkv6 train_4k baseline is
    memory-bound by ~5 orders of magnitude).  The chunkwise form does
    T/chunk sequential steps with dense (C x C) MXU matmuls inside:

      L_t   = cumsum(log w) within the chunk         (per channel)
      r~_j  = r_j * exp(L_{j-1}),  k~_i = k_i * exp(-L_i)
      intra = ((r~ k~^T) o strict_lower) V + diag(r_j . (u o k_j)) v_j
      inter = r~ S_0 ;  S_C = diag(exp(L_C)) S_0 + (k~ o exp(L_C))^T V

    The intra-chunk term uses the exact pairwise log-decay differences
    (L_{j-1} - L_i <= 0 for i < j, so every exp is <= 1 — numerically safe
    for arbitrarily strong decays; the factored r~ k~ form overflows).  The
    (C, C, K) pairwise tensor lives only inside the jax.checkpoint'ed chunk
    body, so backward memory stays O(T/C) states.

    r, k, v, w: (BH, T, K); u: (BH, K) or (K,); S0: (BH, K, K).
    Returns (out (BH, T, K), S_T)."""
    BH, T, K = r.shape
    C = min(chunk, T)
    assert T % C == 0
    nch = T // C
    uh = u if u.ndim == 2 else jnp.broadcast_to(u[None], (BH, K))

    rs = r.reshape(BH, nch, C, K).swapaxes(0, 1).astype(jnp.float32)
    ks = k.reshape(BH, nch, C, K).swapaxes(0, 1).astype(jnp.float32)
    vs = v.reshape(BH, nch, C, K).swapaxes(0, 1).astype(jnp.float32)
    ws = w.reshape(BH, nch, C, K).swapaxes(0, 1).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((C, C), jnp.float32), -1)

    @jax.checkpoint
    def one_chunk(S, xs):
        rc, kc, vc, wc = xs                       # (BH, C, K)
        L = jnp.cumsum(jnp.log(jnp.maximum(wc, 1e-30)), axis=1)  # (BH,C,K)
        Lprev = jnp.concatenate(
            [jnp.zeros((BH, 1, K), jnp.float32), L[:, :-1]], axis=1)
        # pairwise decay ratios: exp(L_{j-1} - L_i) for i < j (always <= 1)
        D = Lprev[:, :, None, :] - L[:, None, :, :]          # (BH, Cj, Ci, K)
        P = jnp.einsum("bjk,bik,bjik->bji", rc, kc,
                       jnp.exp(jnp.minimum(D, 0.0))) * mask[None]
        intra = jnp.einsum("bji,bik->bjk", P, vc)
        diag = jnp.sum(rc * uh[:, None] * kc, axis=-1, keepdims=True) * vc
        r_t = rc * jnp.exp(Lprev)                 # <= |r| (safe)
        inter = jnp.einsum("bik,bkv->biv", r_t, S)
        out = inter + intra + diag
        aC = L[:, -1]                             # (BH, K) log total decay
        kS = kc * jnp.exp(aC[:, None] - L)        # exp(L_C - L_i) <= 1
        S_new = jnp.exp(aC)[:, :, None] * S + jnp.einsum(
            "bik,biv->bkv", kS, vc)
        return S_new, out

    S_T, outs = jax.lax.scan(one_chunk, S0.astype(jnp.float32),
                             (rs, ks, vs, ws))
    out = outs.swapaxes(0, 1).reshape(BH, T, K)
    return out, S_T


def _wkv_with_state(r, k, v, w, u, S0):
    """WKV with explicit initial state (decode path); (BH, T, K) operands."""
    uh = jnp.repeat(u[None], r.shape[0] // u.shape[0], 0).reshape(
        r.shape[0], u.shape[1]) if u.ndim == 2 else u

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[:, :, None] * vt[:, None, :]
        out = (rt[:, :, None] * (S + uh[:, :, None] * kv)).sum(axis=1)
        return wt[:, :, None] * S + kv, out

    S, out = jax.lax.scan(step, S0,
                          (r.swapaxes(0, 1), k.swapaxes(0, 1),
                           v.swapaxes(0, 1), w.swapaxes(0, 1)))
    return out.swapaxes(0, 1), S


def channel_mix(p, x, shift_state=None):
    xs = _token_shift(x, shift_state)
    xk = x * p["cmix_k"] + xs * (1 - p["cmix_k"])
    h = jnp.square(jax.nn.relu(xk @ p["w_ck"]))
    r = jax.nn.sigmoid(x @ p["w_cr"])
    return r * (h @ p["w_cv"]), x[:, -1:]


def init_rwkv_state(batch, d_model, cfg: RwkvCfg, dtype=jnp.bfloat16):
    H = cfg.n_heads(d_model)
    return {
        "tm_shift": jnp.zeros((batch, 1, d_model), dtype),
        "cm_shift": jnp.zeros((batch, 1, d_model), dtype),
        "wkv": jnp.zeros((batch * H, cfg.head_dim, cfg.head_dim), jnp.float32),
    }
