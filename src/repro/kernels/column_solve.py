"""Pallas TPU kernel: block-tridiagonal (6x6 blocks) column solver.

Paper §2.4: the vertically-implicit momentum/tracer systems couple each
prism's 6 nodes to the prisms above and below.  SLIM assigns one CUDA thread
per column and runs banded Gaussian elimination with a 36-scalar register
buffer.  On TPU one *lane* per column does the same: every 6x6 block entry is
a (BC,)-wide vector, the block-Thomas recurrence

    S_l = D_l - L_l C_{l-1};  C_l = S_l^{-1} U_l;  y_l = S_l^{-1}(b_l - L_l y_{l-1})
    x_{nl-1} = y_{nl-1};      x_l = y_l - C_l x_{l+1}

is swept over layers with the 6x6 elimination fully unrolled (no pivoting —
the operators are strictly diagonally dominant mass + dissipation blocks,
like the paper's).  C_l and y_l are staged in VMEM scratch for the backward
sweep; the 36-entry 'register buffer' of the paper becomes 36 lane-vectors
live in VREGs inside the unrolled elimination.

VMEM budget per grid step (nl=32, k=2, BC=128, f32):
  blocks 3*32*36*128*4 = 2.3 MB, rhs/x 32*12*128*4 = 0.2 MB,
  scratch C 2.3 MB + y 0.2 MB  ->  ~5 MB: fits; BC=256 does not. The §Perf
  sweep therefore fixes BC=128 for nl=32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import dispatch


def _mm6(A, B):
    """(6,6,BC) @ (6,m,BC) per-lane matmul, unrolled."""
    return jnp.einsum("ikc,kmc->imc", A, B)


def _solve6(S, rhs):
    """Per-lane solve of S x = rhs via unrolled Gaussian elimination.

    S: (6, 6, BC); rhs: (6, m, BC).  No pivoting (diagonally dominant)."""
    for col in range(6):
        inv = 1.0 / S[col, col]
        Srow = S[col] * inv                   # (6, BC)
        rrow = rhs[col] * inv                 # (m, BC)
        S = S.at[col].set(Srow)
        rhs = rhs.at[col].set(rrow)
        for r in range(6):
            if r == col:
                continue
            f = S[r, col]
            S = S.at[r].add(-f * Srow)
            rhs = rhs.at[r].add(-f * rrow)
    return rhs


def _block_thomas_kernel(lo_ref, dg_ref, up_ref, b_ref, x_ref, C_ref):
    nl = dg_ref.shape[0]
    k = b_ref.shape[2]

    def fwd(l, carry):
        C_prev, y_prev = carry               # (6,6,BC), (6,k,BC)
        L = lo_ref[l]
        S = dg_ref[l] - _mm6(L, C_prev)
        rhs = jnp.concatenate([up_ref[l], b_ref[l] - _mm6(L, y_prev)], axis=1)
        sol = _solve6(S, rhs)                # (6, 6+k, BC)
        C = sol[:, :6, :]
        y = sol[:, 6:, :]
        C_ref[l] = C
        x_ref[l] = y                         # stash y; fixed in backward sweep
        return C, y

    z6 = jnp.zeros_like(dg_ref[0])
    zk = jnp.zeros_like(b_ref[0])
    jax.lax.fori_loop(0, nl, fwd, (z6, zk))

    def bwd(j, x_next):
        l = nl - 2 - j
        x = x_ref[l] - _mm6(C_ref[l], x_next)
        x_ref[l] = x
        return x

    jax.lax.fori_loop(0, nl - 1, bwd, x_ref[nl - 1])


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def block_thomas_cell(lo: jax.Array, dg: jax.Array, up: jax.Array,
                      b: jax.Array, block_cols: int = 128,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Solve block-tridiagonal systems in cell layout.

    lo, dg, up: (nl, 6, 6, C); b: (nl, 6, k, C); returns x: (nl, 6, k, C).
    lo[0] and up[nl-1] are ignored (set to 0 by the assembler).

    C need not be a multiple of block_cols: ragged tails are padded with
    identity diagonal blocks and zero RHS (solution 0 in the pad lanes) and
    sliced back off.  interpret=None auto-selects: compiled on TPU,
    interpreted elsewhere."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    from ..core.layout import pad_nt
    nl, _, _, C = dg.shape
    k = b.shape[2]
    pad = (-C) % block_cols
    if pad:
        lo = pad_nt(lo, block_cols)
        up = pad_nt(up, block_cols)
        b = pad_nt(b, block_cols)
        # pad columns get the identity system  I x = 0  so the unpivoted
        # elimination never divides by zero
        dg = pad_nt(dg, block_cols).at[:, :, :, C:].add(
            jnp.eye(6, dtype=dg.dtype)[None, :, :, None])
    Cp = C + pad
    grid = (Cp // block_cols,)
    bspec = pl.BlockSpec((nl, 6, 6, block_cols), lambda i: (0, 0, 0, i))
    rspec = pl.BlockSpec((nl, 6, k, block_cols), lambda i: (0, 0, 0, i))
    out = pl.pallas_call(
        _block_thomas_kernel,
        grid=grid,
        in_specs=[bspec, bspec, bspec, rspec],
        out_specs=rspec,
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        scratch_shapes=[pltpu.VMEM((nl, 6, 6, block_cols), dg.dtype)],
        interpret=interpret,
    )(lo, dg, up, b)
    return out[..., :C] if pad else out
