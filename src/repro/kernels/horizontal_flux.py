"""Pallas TPU kernel: fused lateral advective-flux term (paper §2.2).

The hottest horizontal-RHS term is the lateral upwind advective flux: the
interior/exterior field states at the 12 lateral quadrature points of each
prism (2 zeta-Gauss x 3 edges x 2 edge-Gauss), an upwind select against the
signed normal flux speed, and the scatter of speed * f_up back onto the 6
prism nodes.  The seed path materialises every intermediate — the
(k, nl, 2qz, 3, 2qs, nt) qp arrays are 12x the field size — between XLA
ops; SLIM's CUDA kernel never leaves registers (Klöckner et al.: fusing the
face-gather with the flux evaluation is the decisive optimisation).

On TPU one *lane* per prism column does the same in cell layout.  The
irregular part — the neighbour gather — is done *outside* by XLA at nodal
size: a TPU lane cannot gather from arbitrary other lanes, so the gather
crosses HBM once at (3 edge x 2 node) nodal width (with boundary fixups
already applied nodally; they are linear, see core/dg3d.py) instead of the
12-qp width.  Everything downstream — vertical zeta-interp, edge s-interp,
upwind select, speed multiply, weighted edge scatter with the vertical
test-function split — is fused here, with the interpolation constants baked
in as trace-time scalars and the (3, BC) accumulators living in VREGs
across the unrolled edge/qp/zeta loops.

Layouts (C = lane axis = prism columns; rows follow core/layout.py):
  f     (nl*6, C)    nodal field, row = layer*6 + node
  fext  (nl*12, C)   neighbour nodal values, row = l*12 + e*4 + j*2 + v
                     (e: edge, j: facing my node a|b, v: top|bottom face)
  speed (nl*12, C)   signed normal flux speed, row = l*12 + z*6 + e*2 + q
                     (z: zeta-Gauss level, q: edge-Gauss point)
  wq    (6, C)       edge quadrature weights edge_len * W_GAUSS, row = e*2+q
  out   (nl*6, C)    assembled lateral term  <<phi f_up speed Jl>>

Ragged C is zero-padded to the 128-lane cell width and sliced back: the
term is purely multiplicative (speed 0 in pad lanes -> contribution 0), so
zero padding is the identity here — the counterpart of the identity-block
scheme in column_solve.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dispatch
from ..core import geometry as _G

# trace-time interpolation constants (exact f64 python floats)
_EDGE_A = [int(a) for a in _G.EDGE_A]
_EDGE_B = [int(b) for b in _G.EDGE_B]
_PHIA = [float(1.0 - s) for s in _G.S_GAUSS]   # node-a basis at the 2 edge qps
_PHIB = [float(s) for s in _G.S_GAUSS]
_PZ = [[float(_G.PHI_ZQ[z, v]) for v in range(2)] for z in range(2)]


def _lateral_flux_kernel(f_ref, fe_ref, sp_ref, wq_ref, out_ref):
    nl = f_ref.shape[0] // 6
    wq = wq_ref[...]                                   # (6, BC)

    def body(l, carry):
        base = l * 6
        ft = f_ref[pl.dslice(base, 3), :]              # (3, BC) top-face nodal
        fb = f_ref[pl.dslice(base + 3, 3), :]          # bottom-face nodal
        ext = fe_ref[pl.dslice(l * 12, 12), :]         # (12, BC)
        spd = sp_ref[pl.dslice(l * 12, 12), :]         # (12, BC)
        acc_t = jnp.zeros_like(ft)
        acc_b = jnp.zeros_like(fb)
        for e in range(3):
            na, nb = _EDGE_A[e], _EDGE_B[e]
            for z in range(2):
                pzt, pzb = _PZ[z]
                # zeta-interp to the Gauss level: interior at my nodes a/b,
                # exterior from the pre-gathered neighbour values
                fi_a = pzt * ft[na] + pzb * fb[na]
                fi_b = pzt * ft[nb] + pzb * fb[nb]
                fe_a = pzt * ext[e * 4 + 0] + pzb * ext[e * 4 + 1]
                fe_b = pzt * ext[e * 4 + 2] + pzb * ext[e * 4 + 3]
                for q in range(2):
                    fi = _PHIA[q] * fi_a + _PHIB[q] * fi_b
                    fe = _PHIA[q] * fe_a + _PHIB[q] * fe_b
                    sp = spd[(z * 3 + e) * 2 + q]
                    g = jnp.where(sp > 0, fi, fe) * sp * wq[e * 2 + q]
                    ca = _PHIA[q] * g                  # node-a test function
                    cb = _PHIB[q] * g
                    acc_t = acc_t.at[na].add(pzt * ca).at[nb].add(pzt * cb)
                    acc_b = acc_b.at[na].add(pzb * ca).at[nb].add(pzb * cb)
        out_ref[pl.dslice(base, 3), :] = acc_t
        out_ref[pl.dslice(base + 3, 3), :] = acc_b
        return carry

    jax.lax.fori_loop(0, nl, body, 0)


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def lateral_flux_cell(f: jax.Array, fext: jax.Array, speed: jax.Array,
                      wq: jax.Array, block_cols: int = 128,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Fused lateral advective term in cell layout (shapes in the module
    docstring).  C need not be a multiple of block_cols; zero-padded lanes
    contribute 0 and are sliced back off.  interpret=None auto-selects:
    compiled on TPU, interpreted elsewhere."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    from ..core.layout import pad_nt
    rows, C = f.shape
    nl = rows // 6
    pad = (-C) % block_cols
    if pad:
        f = pad_nt(f, block_cols)
        fext = pad_nt(fext, block_cols)
        speed = pad_nt(speed, block_cols)
        wq = pad_nt(wq, block_cols)
    Cp = C + pad
    grid = (Cp // block_cols,)
    out = pl.pallas_call(
        _lateral_flux_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block_cols), lambda i: (0, i)),
                  pl.BlockSpec((nl * 12, block_cols), lambda i: (0, i)),
                  pl.BlockSpec((nl * 12, block_cols), lambda i: (0, i)),
                  pl.BlockSpec((6, block_cols), lambda i: (0, i))],
        out_specs=pl.BlockSpec((rows, block_cols), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((rows, Cp), f.dtype),
        interpret=interpret,
    )(f, fext, speed, wq)
    return out[:, :C] if pad else out
