"""Kernel-backend dispatch for the ocean hot path.

The paper's speed lives in the layout/kernel plumbing (§2.1-2.4), so which
implementation runs — the column solvers (block-Thomas, matrix-free r/w),
the cell transpose, and the fused lateral-flux kernel
(kernels/horizontal_flux.py) — must be an explicit, testable choice rather
than an accident of import order:

  * ``Backend.REF``              — pure-jnp references (``kernels/ref.py`` /
                                   ``core/vertical.py``); XLA fuses these well
                                   and they are the equivalence oracles.
  * ``Backend.PALLAS_INTERPRET`` — the Pallas kernels run through the Pallas
                                   interpreter.  Numerically identical to the
                                   compiled kernels; this is what CPU CI runs
                                   so the kernel code path is exercised on
                                   every test invocation.
  * ``Backend.PALLAS``           — compiled Pallas kernels (TPU/GPU).

``resolve(None)`` / ``resolve("auto")`` picks PALLAS on TPU,
PALLAS_INTERPRET on CPU (same kernel code everywhere it can run), and REF on
other accelerators (the kernels use TPU memory spaces and do not lower
through the Pallas GPU backend).  ``OceanConfig.backend`` feeds straight
into this.
"""
from __future__ import annotations

import enum
from typing import Optional, Union

import jax

class Backend(str, enum.Enum):
    REF = "ref"
    PALLAS_INTERPRET = "pallas_interpret"
    PALLAS = "pallas"


BackendLike = Optional[Union[str, Backend]]


def auto_backend() -> Backend:
    """TPU runs the kernels compiled; CPU runs them interpreted (so CI
    exercises the kernel code path); other accelerators fall back to ref —
    the kernels use TPU memory spaces (pltpu.VMEM scratch) and do not lower
    through the Pallas GPU backend."""
    plat = jax.default_backend()
    if plat == "tpu":
        return Backend.PALLAS
    if plat == "cpu":
        return Backend.PALLAS_INTERPRET
    return Backend.REF


def resolve(backend: BackendLike = None) -> Backend:
    """Normalise a user-facing backend spec to a Backend member.

    Accepts None/"auto" (platform auto-detect), Backend members, their string
    values, and the legacy ops.py name "kernel" (= auto minus ref)."""
    if backend is None or backend == "auto" or backend == "kernel":
        return auto_backend()
    if isinstance(backend, Backend):
        return backend
    return Backend(backend)


def interpret_default() -> bool:
    """Default `interpret` flag for raw kernel entry points: compiled on
    TPU, interpreted elsewhere.  (The seed hard-coded interpret=True,
    silently interpreting even on TPU.)"""
    return jax.default_backend() != "tpu"


def interpret_flag(backend: Backend) -> bool:
    """The `interpret` flag a resolved non-ref backend implies."""
    return backend is not Backend.PALLAS
