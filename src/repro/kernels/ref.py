"""Pure-jnp oracles for every Pallas kernel (the `ref.py` of each kernel).

Each function is numerically identical (up to fp reassociation) to its
kernel; tests sweep shapes/dtypes and assert allclose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tridiag(dl, d, du, b):
    """Thomas solve, (nl, C) operands. See kernels/tridiag.py."""
    from ..core.turbulence import thomas_solve
    return thomas_solve(dl, d, du, b)


def solve_r_cell(F, area, r_surf):
    """Matrix-free D_vu solve in cell layout: F (nl*6, C), area (1, C)."""
    rows, C = F.shape
    nl = rows // 6
    Ff = F.reshape(nl, 6, C)
    inva = 12.0 / area
    def minv(face):
        # face (nl, 3, C): M_h^{-1} mixes the 3 nodes of each face
        return inva * (face - 0.25 * face.sum(axis=1, keepdims=True))
    gt = minv(Ff[:, 0:3, :])
    gb = minv(Ff[:, 3:6, :])
    s = jnp.cumsum(gt + gb, axis=0)
    rb = r_surf[None] - s
    rt = rb + 2.0 * gb
    return jnp.concatenate([rt, rb], axis=1).reshape(rows, C)


def solve_w_cell(F, area, w_floor):
    rows, C = F.shape
    nl = rows // 6
    Ff = F.reshape(nl, 6, C)
    inva = 12.0 / area
    def minv(face):
        return inva * (face - 0.25 * face.sum(axis=1, keepdims=True))
    gt = minv(Ff[:, 0:3, :])
    gb = minv(Ff[:, 3:6, :])
    s = jnp.flip(jnp.cumsum(jnp.flip(gt + gb, 0), axis=0), 0)
    wt = w_floor[None] + s
    wb = wt - 2.0 * gt
    return jnp.concatenate([wt, wb], axis=1).reshape(rows, C)


def block_thomas_cell(lo, dg, up, b):
    """Block-tridiagonal solve; shapes as kernels/column_solve.py."""
    from ..core.vertical import Blocks, block_thomas_solve
    # core solver wants (k, nl, 6, nt) rhs
    rhs = jnp.moveaxis(b, 2, 0)
    x = block_thomas_solve(Blocks(lo=lo, dg=dg, up=up), rhs)
    return jnp.moveaxis(x, 0, 2)


def lateral_flux_cell(f, fext, speed, wq):
    """Lateral advective-flux term; shapes as kernels/horizontal_flux.py.

    f (nl*6, C) nodal; fext (nl*12, C) neighbour nodal (e, a|b, top|bot);
    speed (nl*12, C) at lateral qps (qz, e, qs); wq (6, C) edge weights.
    Returns (nl*6, C): <<phi f_up speed Jl>> assembled on the 6 prism nodes.
    """
    import numpy as np
    from ..core import geometry as G
    rows, C = f.shape
    nl = rows // 6
    ff = f.reshape(nl, 2, 3, C)                   # (l, top|bot, node, C)
    ext = fext.reshape(nl, 3, 2, 2, C)            # (l, e, a|b, top|bot, C)
    sp = speed.reshape(nl, 2, 3, 2, C)            # (l, qz, e, qs, C)
    w = wq.reshape(3, 2, C)                       # (e, qs, C)
    # single-source quadrature constants from geometry.py
    PZ = jnp.asarray(np.asarray(G.PHI_ZQ))        # (2qz, 2[top,bot])
    pa, pb = G._PHIA, G._PHIB                     # (2qs,) edge basis at qps
    # node-scatter phi tensor = _EDGE_SCATTER without its W_GAUSS factor
    # (the Gauss weights live in wq here)
    P = jnp.asarray(G._EDGE_SCATTER / G.W_GAUSS[None, :, None])
    # zeta-interp to the 2 Gauss levels
    fzi = jnp.einsum("zv,lvnc->lznc", PZ, ff)      # interior nodal at qz
    fze = jnp.einsum("zv,lejvc->lzejc", PZ, ext)   # exterior per edge at qz
    # edge s-interp -> (l, qz, e, qs, C)
    fia = fzi[..., np.asarray(G.EDGE_A), :]
    fib = fzi[..., np.asarray(G.EDGE_B), :]
    fi = fia[..., None, :] * pa[:, None] + fib[..., None, :] * pb[:, None]
    fe = (fze[..., 0, :][..., None, :] * pa[:, None]
          + fze[..., 1, :][..., None, :] * pb[:, None])
    g = jnp.where(sp > 0, fi, fe) * sp * w[None, None]
    nodes = jnp.einsum("eqn,lzeqc->lznc", P, g)
    top = jnp.einsum("z,lznc->lnc", PZ[:, 0], nodes)
    bot = jnp.einsum("z,lznc->lnc", PZ[:, 1], nodes)
    return jnp.concatenate([top, bot], axis=1).reshape(rows, C)


def soa_to_cell(x):
    from ..core import layout
    nl, six, nt = x.shape
    return layout.soa_to_cell(x)


def cell_to_soa(x, nt):
    from ..core import layout
    nc, rows, c = x.shape
    return layout.cell_to_soa(x, rows // 6, 6, nt)


def wkv6(r, k, v, w, u):
    """RWKV6 recurrence via lax.scan: shapes as kernels/wkv6.py."""
    def one_head(r_h, k_h, v_h, w_h):
        def step(S, inp):
            rt, kt, vt, wt = inp
            kv = kt[:, None] * vt[None, :]
            out = (rt[:, None] * (S + u[:, None] * kv)).sum(axis=0)
            return wt[:, None] * S + kv, out
        S0 = jnp.zeros((r.shape[-1], v.shape[-1]), jnp.float32)
        _, out = jax.lax.scan(step, S0, (r_h, k_h, v_h, w_h))
        return out
    return jax.vmap(one_head)(r, k, v, w)


def attention(q, k, v, causal=True, window=None, softcap=None):
    """Dense reference attention: (BH, T, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (d ** 0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    Tq, Tk = q.shape[1], k.shape[1]
    q_ids = jnp.arange(Tq)[:, None]
    k_ids = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask = mask & (k_ids <= q_ids)
    if window is not None:
        mask = mask & (k_ids > q_ids - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def chunked_attention(q, k, v, causal=True, window=None, softcap=None,
                      chunk: int = 1024, q_block: int = 512):
    """Doubly-blocked online-softmax attention (flash-style in plain XLA) —
    the fallback used on CPU/dry-run.  An outer lax.map over query blocks and
    an inner lax.scan over KV chunks keep live buffers at
    O(BH * q_block * chunk) regardless of sequence length (32k prefill cells
    would otherwise need a (BH, T, chunk) score buffer)."""
    BH, Tq, d = q.shape
    Tk = k.shape[1]
    ck = min(chunk, Tk)
    qb = min(q_block, Tq)
    assert Tk % ck == 0 and Tq % qb == 0
    nk = Tk // ck
    nq = Tq // qb
    qs = q.astype(jnp.float32) / (d ** 0.5)
    ks = k.reshape(BH, nk, ck, d).swapaxes(0, 1)
    vs = v.reshape(BH, nk, ck, d).swapaxes(0, 1)

    def one_qblock(args):
        qc, iq = args                          # (BH, qb, d), scalar
        q_ids = iq * qb + jnp.arange(qb)[:, None]

        def body(carry, inp):
            m, l, acc = carry
            kc, vc, j = inp
            s = jnp.einsum("bqd,bkd->bqk", qc, kc.astype(jnp.float32))
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            k_ids = j * ck + jnp.arange(ck)[None, :]
            mask = jnp.ones((qb, ck), bool)
            if causal:
                mask = mask & (k_ids <= q_ids)
            if window is not None:
                mask = mask & (k_ids > q_ids - window)
            s = jnp.where(mask[None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(axis=-1, keepdims=True)
            acc = alpha * acc + jnp.einsum("bqk,bkd->bqd", p,
                                           vc.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((BH, qb, 1), -1e30, jnp.float32),
                jnp.zeros((BH, qb, 1), jnp.float32),
                jnp.zeros((BH, qb, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(body, init, (ks, vs, jnp.arange(nk)))
        return acc / jnp.maximum(l, 1e-30)

    qblocks = qs.reshape(BH, nq, qb, d).swapaxes(0, 1)
    out = jax.lax.map(one_qblock, (qblocks, jnp.arange(nq)))
    return out.swapaxes(0, 1).reshape(BH, Tq, d).astype(q.dtype)
