"""Public jit'd wrappers for the Pallas kernels with platform dispatch.

On TPU the Pallas kernels compile natively (interpret=False); on CPU they
run in interpret mode for validation, or fall back to the pure-jnp refs
(`backend='ref'`) which XLA fuses well — the CPU benchmarks and the dry-run
lowering use the ref path, the kernel tests use interpret mode.
"""
from __future__ import annotations

import functools

import jax

from . import cell_transpose, column_solve, flash_attention, matrix_free
from . import ref as _ref
from . import tridiag as _tridiag
from . import wkv6 as _wkv6


def default_backend() -> str:
    plat = jax.default_backend()
    return "kernel" if plat == "tpu" else "ref"


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def tridiag(dl, d, du, b, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.tridiag(dl, d, du, b)
    return _tridiag.tridiag_cell(dl, d, du, b, interpret=_interp())


def solve_r_cell(F, area, r_surf, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.solve_r_cell(F, area, r_surf)
    return matrix_free.solve_r_cell(F, area, r_surf, interpret=_interp())


def solve_w_cell(F, area, w_floor, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.solve_w_cell(F, area, w_floor)
    return matrix_free.solve_w_cell(F, area, w_floor, interpret=_interp())


def block_thomas_cell(lo, dg, up, b, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.block_thomas_cell(lo, dg, up, b)
    return column_solve.block_thomas_cell(lo, dg, up, b, interpret=_interp())


def soa_to_cell(x, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.soa_to_cell(x)
    return cell_transpose.soa_to_cell(x, interpret=_interp())


def cell_to_soa(x, nt, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.cell_to_soa(x, nt)
    return cell_transpose.cell_to_soa(x, interpret=_interp())[..., :nt]


def wkv6(r, k, v, w, u, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.wkv6(r, k, v, w, u)
    return _wkv6.wkv6(r, k, v, w, u, interpret=_interp())


def attention(q, k, v, causal=True, window=None, softcap=None,
              backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.chunked_attention(q, k, v, causal=causal, window=window,
                                      softcap=softcap)
    return flash_attention.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=_interp())
