"""Public jit'd wrappers for the Pallas kernels with backend dispatch.

Every ocean-path solver routes through `dispatch.Backend`:

  * ref              — pure-jnp references (XLA-fused; equivalence oracles)
  * pallas_interpret — Pallas kernels in interpreter mode (CPU CI)
  * pallas           — compiled Pallas kernels (TPU/GPU)

`backend=None`/"auto" resolves per platform (accelerator -> pallas, CPU ->
pallas_interpret), so the kernel code path is exercised everywhere and never
silently interpreted on an accelerator.  The SoA-level entry points
(`solve_r`, `solve_w`, `block_thomas`) take the stepper's native
(..., nl, 6, nt) shapes, fold any leading component axis into extra cell
columns (columns are independent, so components just widen the lane axis),
run the cell-layout kernel, and unfold — one layout transform in, one out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import cell_transpose, column_solve, dispatch, flash_attention
from . import horizontal_flux, matrix_free
from . import ref as _ref
from . import tridiag as _tridiag
from . import wkv6 as _wkv6
from .dispatch import Backend
from ..obs import metrics as _metrics

CELL = cell_transpose.CELL


def _dispatch_scope(op: str, bk: Backend):
    """Count the kernel dispatch and tag it in the HLO/profile.

    The counter increments when this call site is TRACED (once per compiled
    program), so ``kernel_dispatch`` counts launches per program — the
    quantity the paper's §3.3 launch-latency model multiplies by per-launch
    overhead.  The named scope makes the kernel findable in profiles and in
    the roofline HLO parse."""
    _metrics.default().counter("kernel_dispatch", op=op,
                               backend=bk.value).inc()
    return jax.named_scope(f"kops.{op}.{bk.value}")


def default_backend() -> str:
    """Platform-auto backend name (resolve() also maps the seed-era
    "kernel" alias onto this)."""
    return dispatch.auto_backend().value


# ---------------------------------------------------------------------------
# ocean column solvers — cell-layout signatures
# ---------------------------------------------------------------------------
def tridiag(dl, d, du, b, backend: dispatch.BackendLike = None):
    bk = dispatch.resolve(backend)
    with _dispatch_scope("tridiag", bk):
        if bk is Backend.REF:
            return _ref.tridiag(dl, d, du, b)
        return _tridiag.tridiag_cell(dl, d, du, b,
                                     interpret=dispatch.interpret_flag(bk))


def solve_r_cell(F, area, r_surf, backend: dispatch.BackendLike = None):
    bk = dispatch.resolve(backend)
    with _dispatch_scope("solve_r_cell", bk):
        if bk is Backend.REF:
            return _ref.solve_r_cell(F, area, r_surf)
        return matrix_free.solve_r_cell(
            F, area, r_surf, interpret=dispatch.interpret_flag(bk))


def solve_w_cell(F, area, w_floor, backend: dispatch.BackendLike = None):
    bk = dispatch.resolve(backend)
    with _dispatch_scope("solve_w_cell", bk):
        if bk is Backend.REF:
            return _ref.solve_w_cell(F, area, w_floor)
        return matrix_free.solve_w_cell(
            F, area, w_floor, interpret=dispatch.interpret_flag(bk))


def block_thomas_cell(lo, dg, up, b, backend: dispatch.BackendLike = None):
    bk = dispatch.resolve(backend)
    with _dispatch_scope("block_thomas_cell", bk):
        if bk is Backend.REF:
            return _ref.block_thomas_cell(lo, dg, up, b)
        return column_solve.block_thomas_cell(
            lo, dg, up, b, interpret=dispatch.interpret_flag(bk))


def soa_to_cell(x, backend: dispatch.BackendLike = None):
    bk = dispatch.resolve(backend)
    with _dispatch_scope("soa_to_cell", bk):
        if bk is Backend.REF:
            return _ref.soa_to_cell(x)
        return cell_transpose.soa_to_cell(
            x, interpret=dispatch.interpret_flag(bk))


def cell_to_soa(x, nt, backend: dispatch.BackendLike = None):
    bk = dispatch.resolve(backend)
    with _dispatch_scope("cell_to_soa", bk):
        if bk is Backend.REF:
            return _ref.cell_to_soa(x, nt)
        return cell_transpose.cell_to_soa(
            x, nt=nt, interpret=dispatch.interpret_flag(bk))


# ---------------------------------------------------------------------------
# ocean column solvers — SoA signatures (the stepper hot path)
# ---------------------------------------------------------------------------
def _fold_cols(x, K, nt):
    """(K, a, b, nt) -> (a*b, K*nt): components become extra cell columns."""
    Kk, a, b_, _ = x.shape
    return jnp.moveaxis(x, 0, 2).reshape(a * b_, K * nt)


def _unfold_cols(x, K, nl, nn, nt):
    """(nl*nn, K*nt) -> (K, nl, nn, nt)."""
    return jnp.moveaxis(x.reshape(nl, nn, K, nt), 2, 0)


def _solve_cells(kernel, geom, F, bc, interpret):
    """Shared SoA->cell plumbing for the matrix-free sweeps: fold any
    leading component axis of F (..., nl, 6, nt) into extra cell columns,
    run `kernel` with the per-column boundary values bc (..., 3, nt), and
    unfold."""
    *lead, nl, six, nt = F.shape
    K = 1
    for d in lead:
        K *= d
    Ff = F.reshape(K, nl, six, nt)
    bc = jnp.broadcast_to(bc, (*lead, 3, nt)).reshape(K, 3, nt)
    Fc = _fold_cols(Ff, K, nt)
    bc_c = jnp.moveaxis(bc, 0, 1).reshape(3, K * nt)
    area_c = jnp.tile(geom.area[None, :], (1, K))
    out = kernel(Fc, area_c, bc_c, interpret=interpret)
    return _unfold_cols(out, K, nl, six, nt).reshape(*lead, nl, six, nt)


def solve_r(geom, F, r_surf, backend: dispatch.BackendLike = None):
    """Matrix-free D_vu solve in SoA shapes with backend dispatch.

    F: (..., nl, 6, nt); r_surf: (..., 3, nt) -> (..., nl, 6, nt)."""
    from ..core import vertical
    bk = dispatch.resolve(backend)
    with _dispatch_scope("solve_r", bk):
        if bk is Backend.REF:
            return vertical.solve_r(geom, F, r_surf)
        return _solve_cells(matrix_free.solve_r_cell, geom, F, r_surf,
                            dispatch.interpret_flag(bk))


def solve_w(geom, F, w_floor=None, backend: dispatch.BackendLike = None):
    """Matrix-free D_vd solve in SoA shapes with backend dispatch.

    F: (..., nl, 6, nt); w_floor: (..., 3, nt) or None (impermeable floor)."""
    from ..core import vertical
    bk = dispatch.resolve(backend)
    with _dispatch_scope("solve_w", bk):
        if bk is Backend.REF:
            return vertical.solve_w(geom, F, w_floor)
        if w_floor is None:
            w_floor = jnp.zeros((3, F.shape[-1]), F.dtype)
        return _solve_cells(matrix_free.solve_w_cell, geom, F, w_floor,
                            dispatch.interpret_flag(bk))


def block_thomas(blocks, rhs, backend: dispatch.BackendLike = None):
    """Block-tridiagonal column solve with backend dispatch.

    blocks: vertical.Blocks with (nl, 6, 6, nt) entries; rhs: (k, nl, 6, nt).
    The non-ref path keeps the whole solve in cell layout: the lane axis IS
    the cell column axis (the kernel grid walks 128-wide cells), so the only
    layout work is one moveaxis of the k RHS components in and out."""
    from ..core import vertical
    bk = dispatch.resolve(backend)
    with _dispatch_scope("block_thomas", bk):
        if bk is Backend.REF:
            return vertical.block_thomas_solve(blocks, rhs)
        b = jnp.moveaxis(rhs, 0, 2)                  # (nl, 6, k, nt)
        x = column_solve.block_thomas_cell(
            blocks.lo, blocks.dg, blocks.up, b,
            interpret=dispatch.interpret_flag(bk))
        return jnp.moveaxis(x, 2, 0)


def lateral_flux_term(geom, f, fext, speed,
                      backend: dispatch.BackendLike = None):
    """Fused lateral advective flux term <<phi f_up speed Jl>> in SoA shapes.

    f: (k, nl, 6, nt) nodal fields; fext: (k, nl, 3, 2, 2, nt) post-BC
    neighbour nodal values (edge, a|b, top|bot) from dg3d.edge_ext_nodal6;
    speed: (nl, 2, 3, 2, nt) signed normal flux speed shared by the k
    fields.  Components fold into extra cell columns (speed and edge
    weights are tiled across them); returns (k, nl, 6, nt)."""
    from ..core import geometry as G
    bk = dispatch.resolve(backend)
    with _dispatch_scope("lateral_flux", bk):
        k, nl, _, nt = f.shape
        fc = _fold_cols(f, k, nt)                              # (nl*6, k*nt)
        fe = jnp.moveaxis(fext.reshape(k, nl, 12, nt), 0, 2).reshape(
            nl * 12, k * nt)
        sp = jnp.tile(speed.reshape(nl * 12, nt), (1, k))
        wq = (geom.edge_len[:, None, :]
              * jnp.asarray(G.W_GAUSS)[:, None]).reshape(6, nt)
        wq = jnp.tile(wq, (1, k))
        if bk is Backend.REF:
            out = _ref.lateral_flux_cell(fc, fe, sp, wq)
        else:
            out = horizontal_flux.lateral_flux_cell(
                fc, fe, sp, wq, interpret=dispatch.interpret_flag(bk))
        return _unfold_cols(out, k, nl, 6, nt)


# ---------------------------------------------------------------------------
# model kernels (non-ocean paths keep the historic ref-on-CPU default)
# ---------------------------------------------------------------------------
def _model_default() -> str:
    """Model kernels keep the historic default: compiled on TPU, ref
    elsewhere (XLA fuses the jnp fallbacks well on CPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def wkv6(r, k, v, w, u, backend: str | None = None):
    bk = dispatch.resolve(backend or _model_default())
    if bk is Backend.REF:
        return _ref.wkv6(r, k, v, w, u)
    return _wkv6.wkv6(r, k, v, w, u, interpret=dispatch.interpret_flag(bk))


def attention(q, k, v, causal=True, window=None, softcap=None,
              backend: str | None = None):
    bk = dispatch.resolve(backend or _model_default())
    if bk is Backend.REF:
        return _ref.chunked_attention(q, k, v, causal=causal, window=window,
                                      softcap=softcap)
    return flash_attention.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=dispatch.interpret_flag(bk))
