"""Pallas TPU kernel: matrix-free column solvers for r and w (paper Alg. 1).

The D_vu / D_vd systems reduce to a single sweep per column after applying
M_h^{-1} per face (see core/vertical.py for the derivation).  SLIM's CUDA
kernel holds a 3x2-component accumulator in registers and sweeps layer by
layer; here the accumulator is a (3, BC) VREG-resident array and the sweep
runs over the cell-layout rows (row = layer*6 + node), 128+ columns per lane.

M_h^{-1} x = (12/A) (x - sum(x)/4) needs only the per-column triangle area —
passed as a (1, BC) row — so the kernel never touches an assembled matrix:
the paper's core trick, verbatim on TPU.

Layouts: F, out are (nl*6, C) single-component cell-layout arrays; the ops.py
wrapper maps components/fields.  Note the natural row tile here is 6 rows
(not a multiple of 8 sublanes); the §Perf iteration found reading the full
(nl*6, BC) block once and sweeping in-register to be the right structure
anyway — no per-layer reload.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dispatch


def _minv_face(face, inv_area):
    """M_h^{-1} on a (3, BC) face given (1, BC) 12/area."""
    s = face[0, :] + face[1, :] + face[2, :]
    return inv_area * (face - 0.25 * s[None, :])


def _r_kernel(F_ref, area_ref, rs_ref, out_ref):
    """Top-down sweep: r_b^l = r_b^{l-1} - (g_t + g_b); r_t^l = r_b^l + 2 g_b."""
    rows = F_ref.shape[0]
    nl = rows // 6
    inv_area = 12.0 / area_ref[0, :][None, :]

    def body(l, rb_prev):
        base = l * 6
        gt = _minv_face(F_ref[pl.dslice(base, 3), :], inv_area)
        gb = _minv_face(F_ref[pl.dslice(base + 3, 3), :], inv_area)
        rb = rb_prev - gt - gb
        rt = rb + 2.0 * gb
        out_ref[pl.dslice(base, 3), :] = rt
        out_ref[pl.dslice(base + 3, 3), :] = rb
        return rb

    jax.lax.fori_loop(0, nl, body, rs_ref[...])


def _w_kernel(F_ref, area_ref, wf_ref, out_ref):
    """Bottom-up sweep: w_t^l = w_t^{l+1} + g_t + g_b; w_b^l = w_t^l - 2 g_t."""
    rows = F_ref.shape[0]
    nl = rows // 6
    inv_area = 12.0 / area_ref[0, :][None, :]

    def body(j, wt_next):
        l = nl - 1 - j
        base = l * 6
        gt = _minv_face(F_ref[pl.dslice(base, 3), :], inv_area)
        gb = _minv_face(F_ref[pl.dslice(base + 3, 3), :], inv_area)
        wt = wt_next + gt + gb
        wb = wt - 2.0 * gt
        out_ref[pl.dslice(base, 3), :] = wt
        out_ref[pl.dslice(base + 3, 3), :] = wb
        return wt

    jax.lax.fori_loop(0, nl, body, wf_ref[...])


def _call(kernel, F, area, bc_vals, block_cols, interpret):
    if interpret is None:
        interpret = dispatch.interpret_default()
    from ..core.layout import pad_nt
    rows, C = F.shape
    pad = (-C) % block_cols
    if pad:
        F = pad_nt(F, block_cols)
        bc_vals = pad_nt(bc_vals, block_cols)
        # pad lanes get area 1 (not 0) so 12/area stays finite
        area = jnp.pad(area, ((0, 0), (0, pad)), constant_values=1.0)
    Cp = C + pad
    grid = (Cp // block_cols,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block_cols), lambda i: (0, i)),
                  pl.BlockSpec((1, block_cols), lambda i: (0, i)),
                  pl.BlockSpec((3, block_cols), lambda i: (0, i))],
        out_specs=pl.BlockSpec((rows, block_cols), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((rows, Cp), F.dtype),
        interpret=interpret,
    )(F, area, bc_vals)
    return out[:, :C] if pad else out


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def solve_r_cell(F: jax.Array, area: jax.Array, r_surf: jax.Array,
                 block_cols: int = 128,
                 interpret: Optional[bool] = None) -> jax.Array:
    """F: (nl*6, C) cell-layout RHS; area: (1, C); r_surf: (3, C).

    C is padded to a multiple of block_cols (unit area, zero RHS) and sliced
    back; interpret=None auto-selects per platform."""
    return _call(_r_kernel, F, area, r_surf, block_cols, interpret)


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def solve_w_cell(F: jax.Array, area: jax.Array, w_floor: jax.Array,
                 block_cols: int = 128,
                 interpret: Optional[bool] = None) -> jax.Array:
    """F: (nl*6, C) cell-layout RHS; area: (1, C); w_floor: (3, C).

    Same padding/auto-interpret contract as solve_r_cell."""
    return _call(_w_kernel, F, area, w_floor, block_cols, interpret)
