"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence with data-dependent decay.

Per head, with state S in R^{K x V}:
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

This is the same computational motif as the paper's column solvers —
independent sequential recurrences batched across lanes (here the V dim rides
in lanes, the K dim in sublanes, and (batch x heads) is the grid) — which is
why the ocean model's cell-layout insight transfers directly to the rwkv6-3b
architecture (DESIGN.md §6).

The time axis is processed in chunks of T_blk rows; S persists in VMEM
scratch across the chunk grid dimension (sequential innermost dimension).
VMEM per step: (K=64, V=64) state = 16 KB + 4 x (T_blk, 64) operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@functools.partial(jax.jit, static_argnames=("t_block", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, t_block: int = 128, interpret: bool = True):
    """RWKV6 WKV.

    r, k, w: (BH, T, K); v: (BH, T, V); u: (K,). Returns (BH, T, V).
    T % t_block == 0."""
    BH, T, K = r.shape
    V = v.shape[-1]
    assert T % t_block == 0
    grid = (BH, T // t_block)
    tspec = lambda d: pl.BlockSpec((1, t_block, d), lambda b, t: (b, t, 0))

    def kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, S_ref):
        # refs carry a leading block dim of 1; index it at use sites
        # (slicing a ref materialises a value — outputs must stay refs)
        tb = pl.program_id(1)

        @pl.when(tb == 0)
        def _():
            S_ref[...] = jnp.zeros_like(S_ref)

        u_ = u_ref[0, :]

        def body(t, S):
            kt = k_ref[0, t, :]
            vt = v_ref[0, t, :]
            rt = r_ref[0, t, :]
            wt = w_ref[0, t, :]
            kv = kt[:, None] * vt[None, :]
            o_ref[0, t, :] = (rt[:, None] * (S + u_[:, None] * kv)).sum(
                axis=0).astype(o_ref.dtype)
            return wt[:, None] * S + kv

        S_ref[...] = jax.lax.fori_loop(0, t_block, body, S_ref[...])

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tspec(K), tspec(K), tspec(V), tspec(K),
                  pl.BlockSpec((1, K), lambda b, t: (0, 0))],
        out_specs=tspec(V),
        out_shape=jax.ShapeDtypeStruct((BH, T, V), v.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u[None, :])
