"""Pallas TPU kernel: flash attention (forward) with optional causal mask and
logit soft-capping (gemma2) and sliding-window (local) attention.

Canonical TPU structure: grid = (batch*heads, q_blocks, kv_blocks) with the
kv dimension innermost/sequential; running max m, normaliser l, and the
output accumulator persist in VMEM scratch across kv iterations
(online-softmax).  Block shapes default to (128, head_dim) — MXU-aligned.

Used by the LM architectures for train/prefill attention on TPU; the XLA
fallback (ref.chunked_attention) lowers the same math for the CPU dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, softcap, blk_q, blk_k):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0] * scale                       # (blk_q, d)
    k = k_ref[0]                               # (blk_k, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_ids = qb * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_ids = kb * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (k_ids <= q_ids)
    if window is not None:
        mask = mask & (k_ids > q_ids - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # (blk_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "blk_q", "blk_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (BH, Tq, d), k/v: (BH, Tk, d) -> (BH, Tq, d)."""
    BH, Tq, d = q.shape
    Tk = k.shape[1]
    assert Tq % blk_q == 0 and Tk % blk_k == 0
    scale = 1.0 / (d ** 0.5)
    grid = (BH, Tq // blk_q, Tk // blk_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, blk_q=blk_q, blk_k=blk_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((blk_q, 1), jnp.float32),
                        pltpu.VMEM((blk_q, 1), jnp.float32),
                        pltpu.VMEM((blk_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
