"""Pallas TPU kernel: SoA <-> cell layout transposition (paper §2.1.2).

"Thanks to the block-based nature of reads and writes between the cell and
SoA layouts, this kernel nearly achieves peak memory bandwidth."  On TPU the
transform is a per-cell reshape: SoA (nl, 6, nt) slabs of 128 columns become
(nl*6, 128) cell matrices.  Both sides are read/written in full (8,128)-tile
rows, so the kernel is a pure streaming copy — the roofline expectation is
memory-term-bound at ~2x the array footprint, which is what the §Perf
analysis of the lowered HLO shows.

nt need not be a multiple of 128: soa_to_cell zero-pads the column axis up
to the cell width (layout.pad_nt) and cell_to_soa slices back when given the
original nt.  interpret=None auto-selects per platform (dispatch).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dispatch

CELL = 128


def _to_cell_kernel(x_ref, o_ref):
    nl, six, c = x_ref.shape
    o_ref[0] = x_ref[...].reshape(nl * six, c)


def _from_cell_kernel(x_ref, o_ref):
    _, rows, c = x_ref.shape
    nl = rows // 6
    o_ref[...] = x_ref[0].reshape(nl, 6, c)


@functools.partial(jax.jit, static_argnames=("interpret",))
def soa_to_cell(x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    """(nl, 6, nt) -> (ceil(nt/128), nl*6, 128); pads nt up to the cell."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    from ..core.layout import pad_nt
    x = pad_nt(x, CELL)
    nl, six, nt = x.shape
    assert six == 6
    nc = nt // CELL
    return pl.pallas_call(
        _to_cell_kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((nl, 6, CELL), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((1, nl * 6, CELL), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, nl * 6, CELL), x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("nt", "interpret"))
def cell_to_soa(x: jax.Array, nt: Optional[int] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """(nc, nl*6, 128) -> (nl, 6, nt); nt defaults to nc*128 (no padding)."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    nc, rows, c = x.shape
    assert c == CELL and rows % 6 == 0
    nl = rows // 6
    out = pl.pallas_call(
        _from_cell_kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, rows, CELL), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((nl, 6, CELL), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((nl, 6, nc * CELL), x.dtype),
        interpret=interpret,
    )(x)
    if nt is not None and nt != nc * CELL:
        out = out[..., :nt]
    return out
