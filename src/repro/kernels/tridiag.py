"""Pallas TPU kernel: batched tridiagonal (Thomas) solver in cell layout.

Paper §2.4: the GLS turbulence closure has one DOF per prism, giving one
tridiagonal system per column.  SLIM solves 128 columns per 128-thread CUDA
block with perfectly coalesced access in the cell layout.

TPU adaptation (DESIGN.md §2): columns ride in the **lane** dimension —
arrays are (nl, C) with C a multiple of 128.  The sequential forward/backward
sweep runs over rows (layers); every row operation is a native (1, 128*k)
vector op across independent columns.  The VMEM working set per grid step is
4 x nl x BC floats (inputs) + 2 x nl x BC (x, cp scratch); with nl=64 and
BC=256 that is ~400 KB — comfortably inside the ~16 MB VMEM budget, leaving
headroom for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tridiag_kernel(dl_ref, d_ref, du_ref, b_ref, x_ref, cp_ref):
    nl = d_ref.shape[0]
    zero = jnp.zeros_like(d_ref[0, :])

    def fwd(i, carry):
        cp_prev, dp_prev = carry
        a = dl_ref[i, :]
        denom = d_ref[i, :] - a * cp_prev
        cp = du_ref[i, :] / denom
        dp = (b_ref[i, :] - a * dp_prev) / denom
        cp_ref[i, :] = cp
        x_ref[i, :] = dp
        return cp, dp

    jax.lax.fori_loop(0, nl, fwd, (zero, zero))

    def bwd(j, x_next):
        i = nl - 2 - j
        xi = x_ref[i, :] - cp_ref[i, :] * x_next
        x_ref[i, :] = xi
        return xi

    jax.lax.fori_loop(0, nl - 1, bwd, x_ref[nl - 1, :])


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def tridiag_cell(dl: jax.Array, d: jax.Array, du: jax.Array, b: jax.Array,
                 block_cols: int = 128, interpret: bool = True) -> jax.Array:
    """Solve tridiagonal systems; all operands (nl, C), C % block_cols == 0.

    dl[0] / du[nl-1] are ignored. Columns are independent (lanes)."""
    nl, C = d.shape
    assert C % block_cols == 0, (C, block_cols)
    grid = (C // block_cols,)
    spec = pl.BlockSpec((nl, block_cols), lambda i: (0, i))
    return pl.pallas_call(
        _tridiag_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((nl, C), d.dtype),
        scratch_shapes=[pltpu.VMEM((nl, block_cols), d.dtype)],
        interpret=interpret,
    )(dl, d, du, b)
