"""Distributed ocean runtime: shard_map-wrapped split-IMEX stepper.

One device per partition (the paper's one-GPU-per-rank), triangles sharded as
Hilbert stripes, ghost-ring halo exchange via ppermute (halo.py).  All
per-partition data is stacked along a leading axis and sharded over the
flattened device mesh axes, so the same SPMD program runs on 4 test devices
or a 512-chip double pod.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..core import geometry, mesh2d, stepper
from ..core.dg2d import State2D
from ..core.extrusion import VGrid
from ..obs import metrics as _metrics
from . import halo, partition


class DistributedOcean:
    """Builds partition data and the sharded step function."""

    def __init__(self, mesh: mesh2d.Mesh2D, b_nodal: np.ndarray,
                 cfg: stepper.OceanConfig, device_mesh: jax.sharding.Mesh,
                 axes: Sequence[str], halo_depth: Optional[int] = None,
                 dtype=jnp.float32):
        # the shard_map'd local step runs on halo-extended partitions whose
        # local nt varies per rank; pin the column solves to the jnp
        # reference there (the Pallas path is exercised — and equivalence-
        # tested — on the single-device stepper, kernels/dispatch.py)
        if cfg.backend not in ("auto", "ref"):
            warnings.warn(
                f"DistributedOcean: backend={cfg.backend!r} is not supported "
                "in the shard_map'd local step; falling back to 'ref' for "
                "the distributed column solves.", stacklevel=2)
        cfg = dataclasses.replace(cfg, backend="ref")
        self.cfg = cfg
        self.device_mesh = device_mesh
        self.axes = tuple(axes)
        n_parts = int(np.prod([device_mesh.shape[a] for a in self.axes]))
        if halo_depth is None:
            halo_depth = max(1, 3 * cfg.halo_exchange_period)
        self.spec = partition.build_partition(mesh, n_parts, halo_depth)
        self.n_parts = n_parts

        lms = partition.local_meshes(mesh, self.spec)
        geoms = [geometry.geom2d_from_mesh(lm, dtype=dtype) for lm in lms]
        self.geom_stk = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *geoms)
        self.b_stk = jnp.asarray(
            partition.scatter_field(self.spec, np.asarray(b_nodal)), dtype)
        self.tables = halo.tables_from_spec(self.spec, self.axes)
        self.pspec = PartitionSpec(self.axes)

        # static partition facts -> metrics (per-rank halo sizing context
        # for the traced halo.bytes / halo.ppermute counters)
        reg = _metrics.default()
        reg.gauge("distributed.n_parts").set(n_parts)
        reg.gauge("distributed.halo_depth").set(halo_depth)
        reg.gauge("distributed.nt_local").set(self.spec.n_loc)
        reg.gauge("distributed.halo_slots").set(
            sum(int(s.shape[-1]) for s in self.tables.send))

    # -- state scatter/gather -------------------------------------------------
    def scatter_state(self, st: stepper.OceanState) -> stepper.OceanState:
        spec = self.spec
        def sc(x):
            x = np.asarray(x)
            if x.ndim == 0:
                return jnp.broadcast_to(jnp.asarray(x), (spec.n_parts,))
            return jnp.asarray(partition.scatter_field(spec, x))
        return jax.tree_util.tree_map(sc, st)

    def gather_state(self, st_stk: stepper.OceanState) -> stepper.OceanState:
        spec = self.spec
        def ga(x):
            x = np.asarray(x)
            if x.ndim == 1:       # time
                return jnp.asarray(x[0])
            return jnp.asarray(partition.gather_field(spec, x))
        return jax.tree_util.tree_map(ga, st_stk)

    def init_state(self) -> stepper.OceanState:
        """Stacked initial state (already partitioned)."""
        nt_loc = self.spec.n_loc
        # build on a dummy geom of local size
        geom0 = jax.tree_util.tree_map(lambda x: x[0], self.geom_stk)
        vg = VGrid(b=self.b_stk[0], nl=self.cfg.nl)
        st = stepper.init_state(geom0, vg)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.n_parts,) + x.shape),
            st)

    # -- the sharded step -------------------------------------------------------
    def make_step(self, forcing: Optional[stepper.Forcing3D] = None):
        cfg = self.cfg
        forcing = forcing if forcing is not None else stepper.Forcing3D()

        def local_step(geom_s, b_s, tables_s, state_s):
            geom = halo.squeeze_local(geom_s)
            b = b_s[0]
            tables = halo.squeeze_local(tables_s)
            st = halo.squeeze_local(state_s)
            vg = VGrid(b=b, nl=cfg.nl)

            def ex2d(s2):
                eta, qx, qy = halo.exchange_batch(
                    [s2.eta, s2.qx, s2.qy], tables)
                return State2D(eta, qx, qy)

            exf = lambda f: halo.exchange(f, tables)
            with jax.named_scope("distributed.local_step"):
                st1 = stepper.step(geom, vg, cfg, st, forcing,
                                   exchange2d=ex2d, exchange_field=exf)
            return jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], st1)

        shmap = jax.shard_map(
            local_step, mesh=self.device_mesh,
            in_specs=(self.pspec, self.pspec, self.pspec, self.pspec),
            out_specs=self.pspec, check_vma=False)

        def step_fn(state_stk):
            return shmap(self.geom_stk, self.b_stk, self.tables, state_stk)

        return jax.jit(step_fn)

    def make_step_args(self, forcing: Optional[stepper.Forcing3D] = None):
        """Un-closed variant for the dry-run: the shard-mapped step as a
        function of (geom, b, tables, state) so it can be lowered with
        ShapeDtypeStruct arguments (no allocation at GBR scale)."""
        cfg = self.cfg
        forcing = forcing if forcing is not None else stepper.Forcing3D()

        def local_step(geom_s, b_s, tables_s, state_s):
            geom = halo.squeeze_local(geom_s)
            b = b_s[0]
            tables = halo.squeeze_local(tables_s)
            st = halo.squeeze_local(state_s)
            vg = VGrid(b=b, nl=cfg.nl)

            def ex2d(s2):
                eta, qx, qy = halo.exchange_batch(
                    [s2.eta, s2.qx, s2.qy], tables)
                return State2D(eta, qx, qy)

            exf = lambda f: halo.exchange(f, tables)
            with jax.named_scope("distributed.local_step"):
                st1 = stepper.step(geom, vg, cfg, st, forcing,
                                   exchange2d=ex2d, exchange_field=exf)
            return jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], st1)

        return jax.shard_map(
            local_step, mesh=self.device_mesh,
            in_specs=(self.pspec, self.pspec, self.pspec, self.pspec),
            out_specs=self.pspec, check_vma=False)

    def abstract_args(self):
        """ShapeDtypeStruct stand-ins for (geom, b, tables, state)."""
        sds = lambda t: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        return (sds(self.geom_stk), sds(self.b_stk), sds(self.tables),
                sds(self.init_state()))
