"""Halo exchange on device (paper §3.1-3.3), shard_map + ppermute.

Each exchange offset becomes one `ppermute` ring shift over the flattened
device axes; gather (pack) and scatter (unpack) are the paper's pack/unpack
kernels, fused here into the surrounding XLA program.  Emitting the pack +
ppermute first and the interior compute afterwards lets XLA's latency-hiding
scheduler overlap the collective with interior work — the stream-priority
trick of §3.1 without explicit streams.

The 2D mode's latency wall (§3.3) is attacked structurally: the entire
m-substep external burst is one fused scan (no launch gaps), and with
`exchange_period = j > 1` + a (3j)-deep halo the burst exchanges only every
j-th substep (communication-avoiding halos, beyond-paper opt #2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import partition as part
from ..obs import metrics as _metrics
from ..runtime import chaos as _chaos


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HaloTables:
    """Per-device exchange tables (leaf arrays are the device-local rows)."""
    send: Tuple[jax.Array, ...]     # each (S_off,) int32 local slots to pack
    recv: Tuple[jax.Array, ...]     # each (S_off,) int32 local slots to fill
    offsets: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    n_devices: int = dataclasses.field(metadata=dict(static=True))
    axes: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))


def tables_from_spec(spec: part.PartitionSpec2D,
                     axes: Sequence[str]) -> HaloTables:
    """Stacked (P, S) numpy tables -> HaloTables pytree (stacked; shard_map
    shards the leading axis)."""
    offs = tuple(sorted(spec.tables.keys()))
    send = tuple(jnp.asarray(spec.tables[o][0], jnp.int32) for o in offs)
    recv = tuple(jnp.asarray(spec.tables[o][1], jnp.int32) for o in offs)
    return HaloTables(send=send, recv=recv, offsets=offs,
                      n_devices=spec.n_parts, axes=tuple(axes))


def exchange(x: jax.Array, t: HaloTables) -> jax.Array:
    """Refresh halo slots of one field (..., n_loc). Inside shard_map.

    The metrics counters increment at TRACE time (shapes are static), so
    ``halo.ppermute`` / ``halo.bytes`` record per-rank collective count and
    wire bytes per compiled program — the §3.3 latency-model inputs."""
    P = t.n_devices
    reg = _metrics.default()
    with jax.named_scope("halo.exchange"):
        for off, sidx, ridx in zip(t.offsets, t.send, t.recv):
            buf = x[..., sidx]
            reg.counter("halo.ppermute").inc()
            reg.counter("halo.bytes").inc(buf.size * buf.dtype.itemsize)
            perm = [(i, (i + off) % P) for i in range(P)]
            rbuf = jax.lax.ppermute(buf, t.axes, perm)
            # chaos site: corrupt the received payload (fires at TRACE time,
            # so an armed halo fault is baked into the compiled program —
            # the diagnostics layer must catch it downstream)
            rbuf = _chaos.site("halo.payload", rbuf, offset=off)
            x = x.at[..., ridx].set(rbuf)
    return x


def exchange_tree(tree, t: HaloTables):
    """Exchange every array leaf of a pytree of (..., n_loc) fields."""
    return jax.tree_util.tree_map(lambda x: exchange(x, t), tree)


def exchange_batch(fields, t: HaloTables):
    """Exchange several same-shaped (..., n_loc) fields with ONE ppermute
    per ring offset (fields stacked on a new leading axis) — the paper's
    message aggregation; cuts the 2D mode's collective count by the field
    count (latency is its Amdahl wall, §3.3)."""
    stacked = jnp.stack(fields)
    out = exchange(stacked, t)
    return [out[i] for i in range(len(fields))]


def squeeze_local(tree):
    """Strip the leading per-device axis of size 1 inside shard_map."""
    return jax.tree_util.tree_map(
        lambda x: x[0] if hasattr(x, "ndim") and x.ndim > 0 else x, tree)
