"""Horizontal domain decomposition (paper §3).

The Hilbert-ordered triangle list is cut into P contiguous equal stripes
(one per device — the paper's one-GPU-per-MPI-rank).  Each partition stores a
k-deep layer of ghost triangles from neighbouring partitions; neighbour
accesses on owned triangles hit the ghost layer, which is refreshed by halo
exchanges (distributed/halo.py).

Design points (DESIGN.md §2):
  * ghost-compute: every partition redundantly computes on its ghost ring(s);
    a state exchange at (sub)stage boundaries revalidates them.  A k-deep
    halo allows k flux stages between exchanges (communication-avoiding,
    beyond-paper opt #2) at the cost of (k-1) rings of redundant compute.
  * static shapes: all partitions are padded to the same owned size, halo
    size, and per-offset message size, so one SPMD program serves all ranks
    (ppermute needs uniform buffers).  A trailing "trash" slot absorbs
    scatter targets of padded message entries.
  * exchange topology: with Hilbert stripes the neighbour set is a small set
    of ring offsets (usually +-1, occasionally +-2..4 where the curve
    revisits); each offset becomes one ppermute.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from ..core import mesh2d
from ..core.mesh2d import EDGE_NODES, INTERIOR


@dataclasses.dataclass(frozen=True)
class PartitionSpec2D:
    """Numpy build-time partition description (stacked over partitions)."""
    n_parts: int
    n_own: int                    # owned triangles per partition (uniform)
    n_loc: int                    # own + halo + 1 trash slot
    # local connectivity, stacked (P, 3, n_loc):
    neigh_tri: np.ndarray
    neigh_edge: np.ndarray
    edge_type: np.ndarray
    # global triangle id per local slot (P, n_loc); trash slot repeats slot 0
    glob_ids: np.ndarray
    # per-offset exchange tables: offset -> (send_idx, recv_idx) each (P, S)
    # send entries index local slots to pack; recv entries are local slots
    # (halo or trash) where the arriving buffer lands.
    tables: Dict[int, Tuple[np.ndarray, np.ndarray]]
    owned_mask: np.ndarray        # (P, n_loc) 1.0 for owned slots


def build_partition(mesh: mesh2d.Mesh2D, n_parts: int,
                    halo_depth: int = 1) -> PartitionSpec2D:
    nt = mesh.nt
    assert nt % n_parts == 0, (nt, n_parts)
    n_own = nt // n_parts
    owner = np.arange(nt) // n_own                       # contiguous stripes

    # --- halo sets (k rings of neighbour triangles) --------------------------
    halos: List[np.ndarray] = []
    for p in range(n_parts):
        frontier = np.arange(p * n_own, (p + 1) * n_own)
        seen = set(frontier.tolist())
        halo: List[int] = []
        for _ in range(halo_depth):
            nxt = np.unique(mesh.neigh_tri[frontier].ravel())
            new = [t for t in nxt.tolist() if t not in seen]
            halo.extend(new)
            seen.update(new)
            frontier = np.array(new, dtype=np.int64) if new else np.array([], np.int64)
        halos.append(np.array(sorted(halo), dtype=np.int64))

    n_halo = max(len(h) for h in halos)
    n_loc = n_own + n_halo + 1                           # +1 trash slot
    trash = n_loc - 1

    glob_ids = np.zeros((n_parts, n_loc), np.int64)
    g2l = np.full((n_parts, nt), -1, np.int64)
    for p in range(n_parts):
        own = np.arange(p * n_own, (p + 1) * n_own)
        h = halos[p]
        pad = np.full(n_halo - len(h), own[0], np.int64)  # pad w/ own slot 0
        ids = np.concatenate([own, h, pad, own[:1]])
        glob_ids[p] = ids
        g2l[p, own] = np.arange(n_own)
        g2l[p, h] = n_own + np.arange(len(h))

    # --- local connectivity ---------------------------------------------------
    neigh_tri = np.zeros((n_parts, 3, n_loc), np.int64)
    neigh_edge = np.zeros((n_parts, 3, n_loc), np.int64)
    edge_type = np.zeros((n_parts, 3, n_loc), np.int64)
    for p in range(n_parts):
        gids = glob_ids[p]
        gn = mesh.neigh_tri[gids]                         # (n_loc, 3) global
        ln = g2l[p, gn]                                   # local or -1
        # unknown neighbours (outside own+halo) -> self (ghost-compute garbage
        # ring; never read by valid cells)
        self_idx = np.arange(n_loc)[:, None]
        ln = np.where(ln < 0, self_idx, ln)
        et = mesh.edge_type[gids]
        ne = mesh.neigh_edge[gids]
        neigh_tri[p] = ln.T
        neigh_edge[p] = ne.T
        edge_type[p] = et.T

    # --- exchange tables --------------------------------------------------------
    # partition q needs triangle t (owned by o(t)) in its halo -> o(t) sends.
    traffic: Dict[int, List[List[Tuple[int, int]]]] = {}
    for q in range(n_parts):
        for t in halos[q]:
            src = int(owner[t])
            off = (q - src) % n_parts
            traffic.setdefault(off, [[] for _ in range(n_parts)])
            # sender src packs local slot of t; receiver q scatters to its
            # local halo slot of t
            traffic[off][src].append((int(g2l[src, t]), int(g2l[q, t])))

    tables: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for off, per_src in traffic.items():
        S = max(len(v) for v in per_src)
        send = np.zeros((n_parts, S), np.int64)
        recv = np.full((n_parts, S), trash, np.int64)
        for src in range(n_parts):
            pairs = per_src[src]
            dst = (src + off) % n_parts
            for j, (sl, rl) in enumerate(pairs):
                send[src, j] = sl
                recv[dst, j] = rl
            # padded send entries pack slot 0 (garbage) -> receiver scatters
            # them to its trash slot (recv already defaults to trash)
        tables[off] = (send, recv)

    owned_mask = np.zeros((n_parts, n_loc))
    owned_mask[:, :n_own] = 1.0

    return PartitionSpec2D(
        n_parts=n_parts, n_own=n_own, n_loc=n_loc,
        neigh_tri=neigh_tri, neigh_edge=neigh_edge, edge_type=edge_type,
        glob_ids=glob_ids, tables=tables, owned_mask=owned_mask)


def local_meshes(mesh: mesh2d.Mesh2D, spec: PartitionSpec2D):
    """Per-partition Mesh2D objects over the local triangle slots (for
    building local Geom2D); vertex coordinates are shared."""
    out = []
    for p in range(spec.n_parts):
        out.append(mesh2d.Mesh2D(
            xy=mesh.xy,
            tri=mesh.tri[spec.glob_ids[p]],
            neigh_tri=spec.neigh_tri[p].T,
            neigh_edge=spec.neigh_edge[p].T,
            edge_type=spec.edge_type[p].T,
        ))
    return out


def scatter_field(spec: PartitionSpec2D, f_global: np.ndarray) -> np.ndarray:
    """Global (..., nt) nodal field -> stacked local (P, ..., n_loc)."""
    return np.stack([f_global[..., spec.glob_ids[p]]
                     for p in range(spec.n_parts)])


def gather_field(spec: PartitionSpec2D, f_local: np.ndarray) -> np.ndarray:
    """Stacked local (P, ..., n_loc) -> global (..., nt) (owned slots only)."""
    P, n_own = spec.n_parts, spec.n_own
    parts = [f_local[p][..., :n_own] for p in range(P)]
    return np.concatenate(parts, axis=-1)
