"""Ocean-model cells for the multi-pod dry-run (the paper's own workload).

Two configurations:
  * benchmark: the paper's timeline/benchmark mesh class — 210k triangles,
    32 sigma layers (Fig. 2 caption), m=20 external sub-steps;
  * gbr: Great-Barrier-Reef scale — 3.3M triangles (paper §5), 20 layers
    (paper: 10-29 variable; sigma grid uses the mean), reef-belt bathymetry.

Each lowers one full split-IMEX internal step (both stages, both external
bursts, implicit solves, GLS) of the shard_map'd distributed stepper with
ShapeDtypeStruct inputs for the (16,16) and (2,16,16) production meshes.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from ..core import geometry, mesh2d, stepper
from ..distributed.ocean import DistributedOcean


@dataclasses.dataclass(frozen=True)
class OceanCell:
    name: str
    nx: int
    ny: int
    lx: float
    ly: float
    nl: int
    m_2d: int
    dt: float
    depth: float
    reef: bool = False
    halo_exchange_period: int = 0


OCEAN_CELLS = {
    # 2*320*328 = 209,920 triangles (divisible by 512), 32 layers
    "benchmark": OceanCell("benchmark", 320, 328, 512e3, 512e3, 32, 20,
                           60.0, 50.0),
    # 2*1280*1290 = 3,302,400 triangles, GBR-scale
    "gbr": OceanCell("gbr", 1280, 1290, 2000e3, 2600e3, 20, 20, 45.0,
                     120.0, reef=True),
    # communication-avoiding variant of the benchmark (beyond-paper opt #2)
    "benchmark-ca2": OceanCell("benchmark-ca2", 320, 328, 512e3, 512e3, 32,
                               20, 60.0, 50.0, halo_exchange_period=2),
}


def build_cell(cell: OceanCell, device_mesh):
    m = mesh2d.rect_mesh(cell.nx, cell.ny, cell.lx, cell.ly, jitter=0.2,
                         seed=7)
    if cell.reef:
        bf = mesh2d.reef_bathymetry(0.1 * cell.depth, cell.depth, cell.lx,
                                    cell.ly)
    else:
        bf = mesh2d.shelf_bathymetry(0.3 * cell.depth, cell.depth, cell.lx)
    geom = geometry.geom2d_from_mesh(m)
    pts = np.stack([np.asarray(geom.node_x).ravel(),
                    np.asarray(geom.node_y).ravel()], axis=1)
    b = bf(pts).reshape(3, m.nt).astype(np.float32)
    cfg = stepper.OceanConfig(
        nl=cell.nl, dt=cell.dt, m_2d=cell.m_2d, coriolis_f=-4e-5,
        eos_kind="jackett", use_gls=True,
        halo_exchange_period=cell.halo_exchange_period)
    do = DistributedOcean(m, b, cfg, device_mesh,
                          axes=device_mesh.axis_names)
    return do


def lower_ocean(config_name: str, device_mesh):
    cell = OCEAN_CELLS[config_name]
    do = build_cell(cell, device_mesh)
    fn = do.make_step_args()
    args = do.abstract_args()
    lowered = jax.jit(fn).lower(*args)
    aux = dict(arch=f"ocean-{cell.name}", shape=f"nl{cell.nl}_m{cell.m_2d}",
               n_triangles=cell.nx * cell.ny * 2, n_layers=cell.nl,
               model_flops=0.0,
               n_params=0, n_params_active=0)
    return lowered, aux
