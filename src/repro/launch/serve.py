"""Serving launcher: prefill + batched decode for any decoder architecture.

Demonstrates the inference path end-to-end: cache init, prefill via the
full-sequence forward, then jit'd single-token decode steps (greedy).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
      --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_arch, reduce_arch
from ..models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.encoder_only:
        raise SystemExit(f"{arch.name} is encoder-only: no decode path")
    if args.reduced:
        arch = reduce_arch(arch)
    model = Model(arch, dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_len)
    rng = jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                              arch.vocab)

    decode = jax.jit(model.decode_step)
    # prefill by stepping the cache through the prompt (state-correct for
    # all families incl. rwkv/mamba)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, toks[:, t:t + 1], jnp.int32(t))
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    cur = jnp.argmax(logits, axis=-1)[:, None]
    for t in range(args.prompt_len, max_len):
        out.append(cur)
        logits, cache = decode(params, cache, cur, jnp.int32(t))
        cur = jnp.argmax(logits, axis=-1)[:, None]
    jax.block_until_ready(logits)
    t_gen = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={arch.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} tok: {t_prefill:.2f}s; "
          f"decode {args.gen} tok: {t_gen:.2f}s "
          f"({args.gen * args.batch / max(t_gen, 1e-9):.1f} tok/s)")
    print("sample token ids:", [int(x) for x in gen[0][:10]])
    assert bool(jnp.isfinite(logits).all())
    print("OK")


if __name__ == "__main__":
    main()
