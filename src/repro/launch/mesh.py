"""Production device meshes.

Defined as functions (not module constants) so importing never touches jax
device state.  Target: TPU v5e pods — 256 chips (16x16) per pod; the
multi-pod configuration adds a leading "pod" axis (2 x 16 x 16 = 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(n_data: int = 2, n_model: int = 4) -> jax.sharding.Mesh:
    """Small mesh for unit tests under --xla_force_host_platform_device_count."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple:
    """Data-parallel axis names for a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
