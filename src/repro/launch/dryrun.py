"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the train/prefill/decode step is jit-lowered with ShapeDtypeStruct inputs
(no allocation), compiled for the production mesh, and the compiled
artifact's memory analysis, cost analysis and SPMD-partitioned HLO roofline
stats are recorded to JSON (consumed by benchmarks/roofline_table.py and
EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all
  PYTHONPATH=src python -m repro.launch.dryrun --ocean            # SLIM cells
"""
# The VERY FIRST lines: jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..configs import ALL_ARCHS, SHAPES, applicable_shapes, get_arch
from ..models import sharding
from ..models.model import Model, count_params
from ..optim import adamw
from ..roofline import analysis
from .mesh import dp_axes, make_production_mesh


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def lower_cell(arch_name: str, shape_name: str, mesh, zero1: bool = True):
    """Returns (lowered, aux dict)."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    model = Model(arch, dtype=jnp.bfloat16)
    tp, dp = sharding.strategy_for(arch, mesh, shape.global_batch)
    dpa = dp if len(dp) > 1 else dp[0]
    model.logits_sharding = NamedSharding(
        mesh, jax.sharding.PartitionSpec(
            dpa, None,
            tp if tp and arch.vocab % mesh.shape[tp] == 0 else None))
    # sequence parallelism (Megatron-SP style): the residual stream between
    # blocks is sharded over (dp, model) on (batch, seq); GSPMD turns the TP
    # all-reduces into reduce-scatter + all-gather pairs and the saved scan
    # carries shrink by the model-axis size. Enabled when seq divides.
    seq_par = (os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1"
               and tp is not None
               and shape.kind in ("train", "prefill")
               and shape.seq_len % mesh.shape[tp] == 0)
    model.act_sharding = NamedSharding(
        mesh, jax.sharding.PartitionSpec(
            dpa, tp if seq_par else None, None))
    if seq_par:
        model.act_inner_sharding = NamedSharding(
            mesh, jax.sharding.PartitionSpec(dpa, None, None))
    if os.environ.get("REPRO_REMAT_GROUPS", "1") == "1" and \
            shape.kind == "train":
        import math
        ns = model.n_super
        target = int(math.sqrt(ns)) or 1
        divs = [d for d in range(1, ns + 1) if ns % d == 0]
        model.remat_groups = min(divs, key=lambda d: abs(d - target))
    if os.environ.get("REPRO_MOE_DECODE_PIN", "1") == "1" and \
            shape.kind == "decode" and arch.moe is not None and \
            tp is not None and arch.moe.n_experts % mesh.shape[tp] == 0:
        model.moe_hidden_sharding = NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, None, tp, "data"))
    if tp is not None and arch.n_heads % mesh.shape[tp] != 0 and \
            os.environ.get("REPRO_PAD_HEADS", "1") == "1":
        tp_size = mesh.shape[tp]
        model.pad_heads_to = ((arch.n_heads + tp_size - 1)
                              // tp_size) * tp_size
        model.attn_head_sharding = NamedSharding(
            mesh, jax.sharding.PartitionSpec(dpa, tp, None, None))
    params_abs = model.init_abstract()
    pspecs = sharding.param_pspecs(model, mesh, tp=tp)
    psh = _ns(mesh, pspecs)
    batch_abs = model.input_specs(shape)
    bspecs = sharding.batch_pspecs(model, shape, mesh, dp=dp,
                                   tp=tp or "model")
    bsh = _ns(mesh, bspecs)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        ospecs = adamw.AdamWState(
            m=sharding.opt_pspecs(pspecs, params_abs, mesh, zero1=zero1),
            v=sharding.opt_pspecs(pspecs, params_abs, mesh, zero1=zero1),
            step=jax.sharding.PartitionSpec())
        osh = _ns(mesh, ospecs)

        mb = int(os.environ.get("REPRO_MICROBATCH", "1"))

        def train_step(params, opt, batch):
            if mb > 1 and shape.global_batch % mb == 0:
                # gradient accumulation: activation working set scales 1/mb
                bsz = shape.global_batch // mb

                def micro(carry, i):
                    gacc, lacc = carry
                    mbatch = jax.tree_util.tree_map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * bsz, bsz, 0), batch)
                    loss, grads = jax.value_and_grad(model.loss)(
                        params, mbatch)
                    gacc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32) / mb,
                        gacc, grads)
                    return (gacc, lacc + loss / mb), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(
                    micro, (g0, jnp.zeros((), jnp.float32)),
                    jnp.arange(mb))
            else:
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt = adamw.update(grads, opt, params)
            return params, opt, loss

        fn = jax.jit(train_step, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        fn = jax.jit(model.prefill, in_shardings=(psh, bsh))
        lowered = fn.lower(params_abs, batch_abs)
    else:  # decode
        csh = bsh["cache"]
        fn = jax.jit(model.decode_step,
                     in_shardings=(psh, csh, bsh["tokens"], bsh["pos"]),
                     out_shardings=(None, csh),
                     donate_argnums=(1,))
        lowered = fn.lower(params_abs, batch_abs["cache"],
                           batch_abs["tokens"], batch_abs["pos"])

    n_total, n_active = count_params(model)
    mf = analysis.model_flops_estimate(arch, shape, n_total, n_active)
    return lowered, dict(arch=arch_name, shape=shape_name,
                         n_params=n_total, n_params_active=n_active,
                         model_flops=mf)


def compile_and_analyze(lowered, aux, mesh, verbose=True):
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    t0 = time.time()
    stats = analysis.analyze_hlo_text(compiled.as_text())
    t_parse = time.time() - t0
    roof = analysis.roofline_from_stats(
        stats, mesh.size, aux.get("model_flops", 0.0),
        cost_analysis_flops=float(ca.get("flops", 0.0)))
    rec = dict(
        aux,
        mesh_shape=list(mesh.devices.shape),
        chips=mesh.size,
        compile_s=round(t_compile, 2),
        parse_s=round(t_parse, 2),
        memory=dict(
            argument_bytes=int(mem.argument_size_in_bytes),
            output_bytes=int(mem.output_size_in_bytes),
            temp_bytes=int(mem.temp_size_in_bytes),
            alias_bytes=int(mem.alias_size_in_bytes),
            peak_per_device=int(mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
        ),
        cost_analysis=dict(flops=float(ca.get("flops", -1)),
                           bytes_accessed=float(ca.get("bytes accessed", -1))),
        hlo=dict(flops=stats.flops, bytes=stats.bytes,
                 coll_bytes=stats.coll_bytes,
                 n_collectives=stats.n_collectives,
                 coll_by_kind=stats.coll_by_kind,
                 bytes_by_source=stats.bytes_by_source),
        roofline=roof.to_dict(),
    )
    if verbose:
        r = rec["roofline"]
        print(f"  mem/dev={rec['memory']['peak_per_device']/2**30:.2f}GiB "
              f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
              f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
              f"useful={r['useful_ratio']:.2f} "
              f"roofline_frac={r['roofline_fraction']:.3f} "
              f"[compile {rec['compile_s']}s]", flush=True)
    return rec


def run_lm_cells(arch_names, shape_names, meshes, out_dir, zero1=True):
    os.makedirs(out_dir, exist_ok=True)
    failures = []
    for mesh_name, mesh in meshes.items():
        for an in arch_names:
            arch = get_arch(an)
            shapes = [s for s in applicable_shapes(arch)
                      if shape_names == "all" or s in shape_names]
            for sn in shapes:
                tag = f"{mesh_name}/{an}_{sn}"
                out_path = os.path.join(out_dir, mesh_name,
                                        f"{an}__{sn}.json")
                os.makedirs(os.path.dirname(out_path), exist_ok=True)
                if os.path.exists(out_path):
                    print(f"[skip] {tag} (cached)", flush=True)
                    continue
                print(f"[cell] {tag}", flush=True)
                try:
                    lowered, aux = lower_cell(an, sn, mesh, zero1=zero1)
                    rec = compile_and_analyze(lowered, aux, mesh)
                    with open(out_path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((tag, repr(e)))
    return failures


def run_ocean_cells(meshes, out_dir, configs=("benchmark",)):
    """Dry-run the SLIM ocean model itself on the production meshes."""
    from . import ocean_dryrun
    os.makedirs(out_dir, exist_ok=True)
    failures = []
    for mesh_name, mesh in meshes.items():
        for cname in configs:
            tag = f"{mesh_name}/ocean-{cname}"
            out_path = os.path.join(out_dir, mesh_name,
                                    f"ocean-{cname}.json")
            os.makedirs(os.path.dirname(out_path), exist_ok=True)
            if os.path.exists(out_path):
                print(f"[skip] {tag} (cached)", flush=True)
                continue
            print(f"[cell] {tag}", flush=True)
            try:
                lowered, aux = ocean_dryrun.lower_ocean(cname, mesh)
                rec = compile_and_analyze(lowered, aux, mesh)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:
                traceback.print_exc()
                failures.append((tag, repr(e)))
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--ocean", action="store_true")
    ap.add_argument("--ocean-config", default="benchmark")
    ap.add_argument("--no-zero1", action="store_true")
    args = ap.parse_args()

    meshes = {}
    if args.mesh in ("single", "both"):
        meshes["single_pod"] = make_production_mesh(multi_pod=False)
    if args.mesh in ("multi", "both"):
        meshes["multi_pod"] = make_production_mesh(multi_pod=True)

    if args.ocean:
        fails = run_ocean_cells(meshes, args.out,
                                configs=args.ocean_config.split(","))
    else:
        archs = sorted(ALL_ARCHS) if args.arch == "all" \
            else args.arch.split(",")
        shapes = "all" if args.shape == "all" else args.shape.split(",")
        fails = run_lm_cells(archs, shapes, meshes, args.out,
                             zero1=not args.no_zero1)
    if fails:
        print("FAILURES:")
        for tag, err in fails:
            print(" ", tag, err)
        raise SystemExit(1)
    print("dry-run complete: all cells compiled.")


if __name__ == "__main__":
    main()
