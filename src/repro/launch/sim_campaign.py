"""Fault-tolerant simulation campaign launcher (and chaos-test harness).

Runs the standing-wave ocean case through ``SimulationRunner``: compiled
``step_with_diagnostics`` steps, a halt-mode ``MonitorPolicy``, periodic
verified checkpoints, and the graceful-degradation dt ladder.  With
``--fault`` specs (``kind@site[:k=v,...]``, see ``runtime/chaos.py``) the
same campaign runs under a seeded ``FaultPlan`` — the reproduce-a-recovery
entry point documented in README "Resilience":

  PYTHONPATH=src python -m repro.launch.sim_campaign --steps 12 \
      --ckpt-every 3 --fault poison_nan@sim.state:step=7,field=T

The builders here are the single source of the tiny campaign case used by
``scripts/chaos_smoke.py`` and ``tests/test_chaos.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..core import dg2d, geometry, mesh2d, stepper
from ..core.extrusion import VGrid
from ..runtime import chaos
from ..runtime.fault_tolerance import (LadderConfig, RunnerConfig,
                                       SimulationRunner)


@dataclasses.dataclass(frozen=True)
class Case:
    geom: object
    vg: VGrid
    cfg: stepper.OceanConfig
    state: stepper.OceanState


def build_case(nx: int = 6, ny: int = 5, lx: float = 2000.0,
               ly: float = 1500.0, depth: float = 20.0, nl: int = 4,
               dt: float = 5.0, m_2d: int = 6, amp: float = 0.05,
               dtype=jnp.float64, seed: int = 3) -> Case:
    """Tiny standing-wave case (the obs-smoke configuration)."""
    m = mesh2d.rect_mesh(nx, ny, lx, ly, jitter=0.2, seed=seed)
    geom = geometry.geom2d_from_mesh(m, dtype=dtype)
    cfg = stepper.OceanConfig(dt=dt, nl=nl, m_2d=m_2d)
    vg = VGrid(b=jnp.full((3, m.nt), depth, dtype), nl=nl)
    st = stepper.init_state(geom, vg, dtype=dtype)
    eta = (amp * jnp.cos(jnp.pi * geom.node_x / lx)).astype(dtype)
    st = dataclasses.replace(st, ext=dg2d.State2D(eta, st.ext.qx, st.ext.qy))
    return Case(geom=geom, vg=vg, cfg=cfg, state=st)


def make_step_factory(case: Case) -> Callable:
    """step_factory for SimulationRunner: cfg -> jitted
    ``state -> (state, Diagnostics)`` (dt-ladder rungs recompile here)."""
    from ..obs import diagnostics as obs_diag

    def factory(cfg: stepper.OceanConfig):
        return jax.jit(lambda s: obs_diag.step_with_diagnostics(
            case.geom, case.vg, cfg, s))
    return factory


def default_policy(cfl_max: float = 1.0):
    from ..obs import diagnostics as obs_diag
    return obs_diag.MonitorPolicy(cfl_max=cfl_max, on_violation="halt")


def run_campaign(case: Case, n_steps: int, runner_cfg: RunnerConfig,
                 ladder: Optional[LadderConfig] = None,
                 policy=None, plan: Optional[chaos.FaultPlan] = None,
                 resume: bool = True):
    """One campaign leg; returns (final_state, runner).  A preempted leg
    returns early with a blocking checkpoint on disk — rerun with
    ``resume=True`` to finish (what the scheduler does after SIGTERM)."""
    runner = SimulationRunner(make_step_factory(case), case.cfg, runner_cfg,
                              policy=policy, ladder=ladder)
    ctx = chaos.active(plan) if plan is not None else _null_ctx()
    with ctx:
        out = runner.run(case.state, n_steps, resume=resume)
    return out, runner


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--nx", type=int, default=6)
    ap.add_argument("--ny", type=int, default=5)
    ap.add_argument("--nl", type=int, default=4)
    ap.add_argument("--dt", type=float, default=5.0)
    ap.add_argument("--ckpt", default="checkpoints/sim")
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--cfl-max", type=float, default=1.0)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--fault", action="append", default=[],
                    help="chaos spec kind@site[:k=v,...] (repeatable)")
    ap.add_argument("--seed", type=int, default=0, help="FaultPlan seed")
    ap.add_argument("--metrics", default=None,
                    help="JSONL metrics sink path")
    args = ap.parse_args(argv)

    jax.config.update("jax_enable_x64", True)
    from ..obs import metrics as obs_metrics
    if args.metrics:
        obs_metrics.configure(args.metrics)

    case = build_case(nx=args.nx, ny=args.ny, nl=args.nl, dt=args.dt)
    runner_cfg = RunnerConfig(checkpoint_dir=args.ckpt,
                              checkpoint_every=args.ckpt_every,
                              max_retries=args.max_retries,
                              backoff_base_s=0.01)
    plan = (chaos.plan_from_specs(args.fault, seed=args.seed)
            if args.fault else None)
    st, runner = run_campaign(case, args.steps, runner_cfg,
                              policy=default_policy(args.cfl_max),
                              plan=plan, resume=not args.no_resume)
    print(f"steps={runner.stats['steps']} retries={runner.stats['retries']} "
          f"cold_restores={runner.stats['cold_restores']} "
          f"ladder={runner.stats['ladder_transitions']} "
          f"preempted={runner.stats['preempted']} "
          f"t={float(st.time):.1f}s")
    if plan is not None:
        for rec in plan.log:
            print(f"chaos fired: {rec}")
    if args.metrics:
        obs_metrics.default().flush(step=args.steps)
        obs_metrics.default().close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
