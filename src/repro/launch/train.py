"""Training launcher: any assigned architecture, any device topology.

Full production path: arch config -> sharded params/optimizer (TP+FSDP+ZeRO
per models/sharding) -> fault-tolerant runner (async checkpoints, resume,
retry, preemption) -> deterministic data pipeline.

On this CPU container use --reduced (and optionally
XLA_FLAGS=--xla_force_host_platform_device_count=8) to exercise the whole
path; on a real pod, drop --reduced and point --mesh at the production shape.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..configs import get_arch, reduce_arch
from ..data.pipeline import TokenDataset
from ..models import sharding
from ..models.model import Model, count_params
from ..optim import adamw
from ..runtime.fault_tolerance import RunnerConfig, TrainRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 => ('data','model'); default: all "
                         "devices on 'data'")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduce_arch(arch)
    model = Model(arch, dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    total, active = count_params(model)
    print(f"arch={arch.name} params={total / 1e6:.1f}M "
          f"(active {active / 1e6:.1f}M)")

    n_dev = jax.device_count()
    if args.mesh:
        shp = tuple(int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh(shp, ("data", "model")[:len(shp)],
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(shp))
    else:
        mesh = jax.make_mesh((n_dev,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    tp = "model" if "model" in mesh.axis_names else None
    pspecs = sharding.param_pspecs(model, mesh, tp=tp,
                                   fsdp="data" if n_dev > 1 else None)
    ns = lambda t: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    params = jax.jit(model.init, out_shardings=ns(pspecs))(
        jax.random.PRNGKey(0))
    opt = adamw.init(params)
    opt_cfg = adamw.AdamWConfig(lr=args.lr)
    ds = TokenDataset(vocab=arch.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0)

    @jax.jit
    def train_step(state, batch):
        params, opt = state
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt = adamw.update(grads, opt, params, opt_cfg)
        return (params, opt), loss

    losses = []

    def step_fn(state, batch):
        state, loss = train_step(state, batch)
        losses.append(float(loss))
        if len(losses) % 10 == 0:
            print(f"step {len(losses)} loss {losses[-1]:.4f}", flush=True)
        return state, {"loss": loss}

    runner = TrainRunner(step_fn, ds, RunnerConfig(
        checkpoint_dir=args.ckpt, checkpoint_every=args.ckpt_every))
    runner.run((params, opt), n_steps=args.steps)
    print(f"done; stats={runner.stats}")


if __name__ == "__main__":
    main()
