"""Data pipelines.

LM side: deterministic, shard-aware token batching from a synthetic stream or
a memory-mapped token file (u16/u32 .bin).  Each host slices its own batch
rows; resume is exact (the iterator state is just the step counter).

Ocean side: time-interpolated external forcing (paper §2.5): forcing fields
vary linearly between two precomputed states ~1 h apart; the interpolation
happens on device inside the compiled step (no per-step host transfer), and
the host swaps in the next window asynchronously when the simulation time
leaves the current one.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TokenDataset:
    """Deterministic token batch source."""
    vocab: int
    seq_len: int
    global_batch: int
    data: Optional[np.ndarray] = None     # memmap or array of token ids
    seed: int = 0

    @classmethod
    def from_file(cls, path: str, vocab: int, seq_len: int,
                  global_batch: int, dtype=np.uint16) -> "TokenDataset":
        data = np.memmap(path, dtype=dtype, mode="r")
        return cls(vocab=vocab, seq_len=seq_len, global_batch=global_batch,
                   data=data)

    def batch_at(self, step: int) -> dict:
        """Batch for a given step (resumable by construction)."""
        B, T = self.global_batch, self.seq_len
        if self.data is not None:
            n_tok = len(self.data) - (T + 1)
            rng = np.random.default_rng(self.seed + step)
            offs = rng.integers(0, n_tok, size=B)
            toks = np.stack([np.asarray(self.data[o:o + T + 1],
                                        dtype=np.int32) for o in offs])
        else:
            # synthetic but LEARNABLE: noisy affine bigram process
            # (next = 31*prev+7 mod V with p=0.85, else uniform) — a model
            # that learns the bigram reaches ~0.15*log(V) loss
            rng = np.random.default_rng(self.seed + step)
            toks = np.empty((B, T + 1), np.int32)
            toks[:, 0] = rng.integers(0, self.vocab, size=B)
            noise = rng.random(size=(B, T)) > 0.85
            rand = rng.integers(0, self.vocab, size=(B, T), dtype=np.int64)
            for t in range(T):
                nxt = (toks[:, t].astype(np.int64) * 31 + 7) % self.vocab
                toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": jnp.asarray(toks[:, :T]),
                "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


# ---------------------------------------------------------------------------
# Ocean forcing: linear-in-time window interpolation (paper §2.5)
# ---------------------------------------------------------------------------
def interp_forcing(f0: jax.Array, f1: jax.Array, t0: float, t1: float,
                   t: jax.Array) -> jax.Array:
    """On-device linear interpolation between two forcing states."""
    w = jnp.clip((t - t0) / (t1 - t0), 0.0, 1.0)
    return f0 * (1.0 - w) + f1 * w


class ForcingWindow:
    """Holds two forcing states [t0, t1] on device; swaps windows on the host
    side (asynchronously) when the simulation time approaches t1.

    `provider(k)` returns the forcing pytree at window index k (e.g. read
    from disk + spatial interpolation); windows are `dt_window` apart."""

    def __init__(self, provider: Callable[[int], dict], dt_window: float,
                 prefetch: bool = True):
        self.provider = provider
        self.dt = dt_window
        self.k0 = 0
        self.f0 = provider(0)
        self.f1 = provider(1)
        self.prefetch = prefetch
        self._next: Optional[Tuple[int, dict]] = None
        self._thread: Optional[threading.Thread] = None

    def _prefetch(self, k):
        def work():
            self._next = (k, self.provider(k))
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def at(self, t: float):
        """(f0, f1, t0, t1) for simulation time t, advancing windows."""
        k = int(t // self.dt)
        while k > self.k0:
            if self._next is not None and self._next[0] == self.k0 + 2:
                if self._thread is not None:
                    self._thread.join()
                nxt = self._next[1]
            else:
                nxt = self.provider(self.k0 + 2)
            self.f0, self.f1 = self.f1, nxt
            self.k0 += 1
            self._next = None
        if self.prefetch and self._next is None and self._thread is None:
            self._prefetch(self.k0 + 2)
        return self.f0, self.f1, self.k0 * self.dt, (self.k0 + 1) * self.dt


def tidal_forcing_provider(geom, amplitude: float, period: float,
                           phase_fn=None):
    """Synthetic tidal open-boundary elevation provider (GBR example):
    eta_bc(t) sampled at window boundaries, interpolated on device."""
    def provider(k):
        t = k * period / 12.0
        ph = 0.0 if phase_fn is None else phase_fn(geom)
        eta = amplitude * np.cos(2 * np.pi * t / period + ph)
        return {"eta_open": jnp.asarray(
            eta * np.ones((3, geom.nt), np.float32))}
    return provider
