"""Checkpointing: async, atomic, elastic.

Format: one directory per step containing one .npy per pytree leaf (path-
encoded filenames) + meta.json (tree structure, step, mesh shape).  Writes
go to a temp dir then os.rename (atomic on POSIX); a `latest` file points at
the newest complete step; keep_last prunes old steps.

Elastic re-sharding: leaves are stored as GLOBAL arrays, so restoring onto a
different mesh/device-count is just device_put with the new shardings —
rescaling from 256 to 512 chips (or to 8 test devices) needs no resharding
tool.  Async: serialisation happens on a background thread after device_get;
`wait()` joins before the next save (double-buffered checkpointing).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot `tree` at `step`; serialisation is async by default."""
        self.wait()
        flat, _ = _flatten(tree)
        # device_get on the caller thread (cheap on CPU; on TPU this is the
        # D2H copy — still overlapped with the next step's compute because
        # the arrays are snapshots)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def work():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for k, v in host.items():
                np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), v)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "keys": sorted(host.keys())}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, "latest"), "w") as f:
                f.write(os.path.basename(final))
            self._prune()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "meta.json")):
            return None
        return int(name.split("_")[1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `template`.

        shardings: optional matching tree of jax.sharding.Sharding — arrays
        are device_put with them (elastic rescale path)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        flat, treedef = _flatten(template)
        sh_flat = None
        if shardings is not None:
            sh_flat, _ = _flatten(shardings)
        out = {}
        for k in flat:
            arr = np.load(os.path.join(d, k.replace("/", "__") + ".npy"))
            if sh_flat is not None and k in sh_flat:
                out[k] = jax.device_put(arr, sh_flat[k])
            else:
                out[k] = jax.numpy.asarray(arr)
        leaves = [out[k] for k in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves)
