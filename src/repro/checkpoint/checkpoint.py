"""Checkpointing: async, atomic, elastic — and verified.

Format (v2): one directory per step containing one .npy per pytree leaf
(path-encoded filenames) + meta.json holding the tree keys AND a per-leaf
manifest (crc32 checksum, shape, dtype).  Writes go to a temp dir then
os.rename (atomic on POSIX); a `latest` file points at the newest complete
step; keep_last prunes old steps.

Verification: ``restore`` checks every leaf it loads against the manifest
(checksum + shape + dtype) and, when no explicit step was requested, falls
back to the newest *intact* step — a truncated .npy, a missing leaf, or a
stale/dangling ``latest`` pointer costs one checkpoint interval, not the
run.  v1 checkpoints (no manifest) still restore, unverified.

Failure propagation: the async save worker records any exception and the
next ``wait()``/``save()`` re-raises it as ``CheckpointError`` — a failed
background save is loud, never a run that silently believes it is
checkpointed.

Elastic re-sharding: leaves are stored as GLOBAL arrays, so restoring onto a
different mesh/device-count is just device_put with the new shardings —
rescaling from 256 to 512 chips (or to 8 test devices) needs no resharding
tool.  Async: serialisation happens on a background thread after device_get;
`wait()` joins before the next save (double-buffered checkpointing).

Chaos sites (``runtime/chaos.py``): ``checkpoint.write`` fires inside the
worker before files land (injected IOError = disk failure mid-save);
``checkpoint.saved`` fires after the rename (injected corruption hits a
fully-landed checkpoint, exactly what a later restore must survive).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

FORMAT = 2


class CheckpointError(RuntimeError):
    """A checkpoint save failed (possibly on the async worker thread)."""


class CheckpointCorruption(CheckpointError):
    """A checkpoint step failed restore-time verification."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _leaf_file(key: str) -> str:
    return key.replace("/", "__") + ".npy"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _step_name(step: int) -> str:
    return f"step_{step:09d}"


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot `tree` at `step`; serialisation is async by default.

        Raises ``CheckpointError`` here if the PREVIOUS async save failed —
        the error from the worker thread surfaces at the next save/wait."""
        self.wait()
        flat, _ = _flatten(tree)
        # device_get on the caller thread (cheap on CPU; on TPU this is the
        # D2H copy — still overlapped with the next step's compute because
        # the arrays are snapshots)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def work():
            try:
                from ..runtime import chaos
                chaos.site("checkpoint.write", step=step, directory=self.dir)
                tmp = os.path.join(self.dir, f".tmp_step_{step}")
                final = os.path.join(self.dir, _step_name(step))
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(tmp)
                manifest: Dict[str, dict] = {}
                for k, v in host.items():
                    np.save(os.path.join(tmp, _leaf_file(k)), v)
                    manifest[k] = dict(crc32=_crc(v), shape=list(v.shape),
                                       dtype=str(v.dtype))
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump({"step": step, "format": FORMAT,
                               "keys": sorted(host.keys()),
                               "leaves": manifest}, f)
                shutil.rmtree(final, ignore_errors=True)
                os.rename(tmp, final)
                with open(os.path.join(self.dir, "latest"), "w") as f:
                    f.write(os.path.basename(final))
                self._prune()
                chaos.site("checkpoint.saved", step=step, directory=self.dir,
                           path=final)
            except BaseException as e:           # surfaces at next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        """Join any in-flight save; re-raise its failure as CheckpointError."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointError(
                f"async checkpoint save failed: {err!r}") from err

    def _prune(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- verification
    def steps(self) -> List[int]:
        """All step numbers with a step directory on disk (ascending)."""
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def manifest(self, step: int) -> Optional[dict]:
        p = os.path.join(self.dir, _step_name(step), "meta.json")
        try:
            with open(p) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None

    def verify(self, step: int) -> List[str]:
        """Problems with the on-disk checkpoint at ``step`` ([] = intact).

        Checks meta.json, leaf presence, checksum, shape and dtype against
        the manifest.  v1 checkpoints (no manifest) only get existence
        checks."""
        d = os.path.join(self.dir, _step_name(step))
        meta = self.manifest(step)
        if meta is None:
            return [f"{_step_name(step)}: missing/unreadable meta.json"]
        problems = []
        leaves = meta.get("leaves", {})
        for k in meta.get("keys", []):
            path = os.path.join(d, _leaf_file(k))
            if not os.path.exists(path):
                problems.append(f"{k}: leaf file missing")
                continue
            try:
                arr = np.load(path)
            except Exception as e:
                problems.append(f"{k}: unreadable ({e})")
                continue
            info = leaves.get(k)
            if info is None:
                continue                       # v1: nothing to check against
            if list(arr.shape) != list(info["shape"]):
                problems.append(f"{k}: shape {list(arr.shape)} != manifest "
                                f"{info['shape']}")
            if str(arr.dtype) != info["dtype"]:
                problems.append(f"{k}: dtype {arr.dtype} != manifest "
                                f"{info['dtype']}")
            if _crc(arr) != info["crc32"]:
                problems.append(f"{k}: checksum mismatch")
        return problems

    def intact_steps(self) -> List[int]:
        """Steps that pass verification, newest first."""
        return [s for s in reversed(self.steps()) if not self.verify(s)]

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        """Newest step per the ``latest`` pointer — falling back to a
        directory scan when the pointer is missing, stale or dangling."""
        candidates = self.steps()
        p = os.path.join(self.dir, "latest")
        if os.path.exists(p):
            with open(p) as f:
                name = f.read().strip()
            if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                try:
                    pointed = int(name.split("_")[1])
                    # a stale pointer (older than what's on disk) is repaired
                    # by the scan; a fresh one wins
                    if not candidates or pointed >= candidates[-1]:
                        return pointed
                except ValueError:
                    pass
        while candidates:
            s = candidates.pop()
            if os.path.exists(os.path.join(self.dir, _step_name(s),
                                           "meta.json")):
                return s
        return None

    def _load_step(self, step: int, flat: dict, sh_flat: Optional[dict]):
        """Load + verify one step into the template's key set."""
        d = os.path.join(self.dir, _step_name(step))
        meta = self.manifest(step)
        if meta is None:
            raise CheckpointCorruption(
                f"{_step_name(step)}: missing/unreadable meta.json")
        leaves = meta.get("leaves", {})
        out = {}
        for k in flat:
            path = os.path.join(d, _leaf_file(k))
            try:
                arr = np.load(path)
            except FileNotFoundError:
                raise CheckpointCorruption(
                    f"{_step_name(step)}: leaf {k!r} missing")
            except Exception as e:
                raise CheckpointCorruption(
                    f"{_step_name(step)}: leaf {k!r} unreadable: {e}")
            info = leaves.get(k)
            if info is not None:
                if list(arr.shape) != list(info["shape"]):
                    raise CheckpointCorruption(
                        f"{_step_name(step)}: leaf {k!r} shape "
                        f"{list(arr.shape)} != manifest {info['shape']}")
                if str(arr.dtype) != info["dtype"]:
                    raise CheckpointCorruption(
                        f"{_step_name(step)}: leaf {k!r} dtype {arr.dtype} "
                        f"!= manifest {info['dtype']}")
                if _crc(arr) != info["crc32"]:
                    raise CheckpointCorruption(
                        f"{_step_name(step)}: leaf {k!r} checksum mismatch")
            if sh_flat is not None and k in sh_flat:
                out[k] = jax.device_put(arr, sh_flat[k])
            else:
                out[k] = jax.numpy.asarray(arr)
        return out

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `template`, verified.

        ``step=None`` restores the newest INTACT step: corrupt candidates
        are skipped (with a warning via the default metrics registry) until
        one verifies.  An explicitly requested ``step`` raises
        ``CheckpointCorruption`` instead of silently substituting history.

        shardings: optional matching tree of jax.sharding.Sharding — arrays
        are device_put with them (elastic rescale path)."""
        flat, treedef = _flatten(template)
        sh_flat = None
        if shardings is not None:
            sh_flat, _ = _flatten(shardings)

        if step is not None:
            candidates = [step]
            fallback = False
        else:
            latest = self.latest_step()
            if latest is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
            candidates = sorted((s for s in self.steps() if s <= latest),
                                reverse=True)
            fallback = True

        last_err: Optional[CheckpointCorruption] = None
        for s in candidates:
            try:
                out = self._load_step(s, flat, sh_flat)
            except CheckpointCorruption as e:
                last_err = e
                if fallback:
                    from ..obs import metrics as obs_metrics
                    obs_metrics.default().counter(
                        "checkpoint.corrupt_skipped").inc()
                    continue
                raise
            leaves = [out[k] for k in flat]
            return jax.tree_util.tree_unflatten(treedef, leaves)
        raise last_err if last_err is not None else FileNotFoundError(
            f"no checkpoint in {self.dir}")

    def restore_latest(self, template: Any, shardings: Any = None
                       ) -> Tuple[Any, Optional[int]]:
        """(state, step) from the newest intact checkpoint, or (None, None)
        when nothing on disk is restorable — the runner's cold-restart
        decision point."""
        try:
            latest = self.latest_step()
            if latest is None:
                return None, None
            flat, treedef = _flatten(template)
            sh_flat = None
            if shardings is not None:
                sh_flat, _ = _flatten(shardings)
            for s in sorted((x for x in self.steps() if x <= latest),
                            reverse=True):
                try:
                    out = self._load_step(s, flat, sh_flat)
                except CheckpointCorruption:
                    from ..obs import metrics as obs_metrics
                    obs_metrics.default().counter(
                        "checkpoint.corrupt_skipped").inc()
                    continue
                leaves = [out[k] for k in flat]
                return jax.tree_util.tree_unflatten(treedef, leaves), s
            return None, None
        except FileNotFoundError:
            return None, None
