"""Ref vs Pallas cell-layout column solvers across layer counts (paper
Fig. 15 axis): block-Thomas (implicit momentum/tracer, §2.4) and the
matrix-free r/w sweeps (§2.3) for nl in {4, 8, 16, 32} at several column
counts.

On CPU the Pallas side runs interpreted — roughly ref-speed for these
kernels (the unrolled 6x6 elimination competes with batched linalg.solve),
so the CPU rows sanity-check plumbing and relative nl scaling; on TPU both
sides are compiled and the comparison is the paper's actual experiment.
Output rows: name,us_per_call,derived.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vertical import Blocks, block_thomas_solve
from repro.kernels import column_solve, dispatch, matrix_free
from repro.kernels import ref as kref

from .common import row, time_fn

LAYERS = [4, 8, 16, 32]
COLUMNS = [1024, 8192]


def _blocks(rng, nl, C, dtype=np.float32):
    mk = lambda: jnp.asarray(
        rng.normal(size=(nl, 6, 6, C)).astype(dtype)) * 0.1
    lo = mk().at[0].set(0.0)
    up = mk().at[-1].set(0.0)
    dg = mk() + 2.0 * jnp.eye(6, dtype=dtype)[None, :, :, None]
    b = jnp.asarray(rng.normal(size=(nl, 6, 2, C)).astype(dtype))
    return lo, dg, up, b


def run(columns=COLUMNS, layers=LAYERS):
    interp = dispatch.interpret_default()
    mode = "interpret" if interp else "compiled"
    rng = np.random.default_rng(0)

    for C in columns:
        for nl in layers:
            lo, dg, up, b = _blocks(rng, nl, C)

            # ref: the scanned jnp block-Thomas on (k, nl, 6, nt) shapes
            rhs = jnp.moveaxis(b, 2, 0)
            f_ref = jax.jit(lambda l, d, u, r: block_thomas_solve(
                Blocks(l, d, u), r))
            t_ref = time_fn(f_ref, lo, dg, up, rhs, warmup=1, iters=3)

            f_pal = lambda *a: column_solve.block_thomas_cell(
                *a, interpret=interp)
            t_pal = time_fn(f_pal, lo, dg, up, b, warmup=1, iters=3)

            n_sys = C * 2
            row(f"block_thomas_nl{nl}_C{C}_ref", t_ref * 1e6,
                f"ns_per_column_solve={t_ref / n_sys * 1e9:.1f}")
            row(f"block_thomas_nl{nl}_C{C}_pallas_{mode}", t_pal * 1e6,
                f"ns_per_column_solve={t_pal / n_sys * 1e9:.1f};"
                f"speedup_vs_ref={t_ref / t_pal:.2f}x")

        for nl in layers:
            F = jnp.asarray(rng.normal(size=(nl * 6, C)).astype(np.float32))
            area = jnp.abs(
                jnp.asarray(rng.normal(size=(1, C)).astype(np.float32))) + 0.5
            bc = jnp.asarray(rng.normal(size=(3, C)).astype(np.float32))

            sweeps = [("r", kref.solve_r_cell, matrix_free.solve_r_cell),
                      ("w", kref.solve_w_cell, matrix_free.solve_w_cell)]
            for name, f_ref_raw, f_pal_raw in sweeps:
                f_ref = jax.jit(f_ref_raw)
                t_ref = time_fn(f_ref, F, area, bc, warmup=1, iters=3)
                f_pal = lambda *a, _f=f_pal_raw: _f(*a, interpret=interp)
                t_pal = time_fn(f_pal, F, area, bc, warmup=1, iters=3)
                row(f"matrix_free_{name}_nl{nl}_C{C}_ref", t_ref * 1e6,
                    f"GBps={2 * F.size * 4 / t_ref / 1e9:.2f}")
                row(f"matrix_free_{name}_nl{nl}_C{C}_pallas_{mode}",
                    t_pal * 1e6, f"speedup_vs_ref={t_ref / t_pal:.2f}x")


if __name__ == "__main__":
    run()
