"""Render the dry-run JSON records into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import os
from typing import Dict, List

DRYRUN_DIR = "experiments/dryrun_v2"


def load_records(dryrun_dir: str = DRYRUN_DIR) -> Dict[str, List[dict]]:
    out: Dict[str, List[dict]] = {}
    if not os.path.isdir(dryrun_dir):
        return out
    for mesh_name in sorted(os.listdir(dryrun_dir)):
        mdir = os.path.join(dryrun_dir, mesh_name)
        if not os.path.isdir(mdir):
            continue
        recs = []
        for fn in sorted(os.listdir(mdir)):
            if fn.endswith(".json"):
                with open(os.path.join(mdir, fn)) as f:
                    recs.append(json.load(f))
        out[mesh_name] = recs
    return out


def fmt_table(recs: List[dict]) -> str:
    hdr = ("| arch | shape | mem/dev GiB | compute ms | memory ms | "
           "collective ms | dominant | useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['memory']['peak_per_device'] / 2**30:.2f} "
            f"| {ro['compute_s'] * 1e3:.1f} | {ro['memory_s'] * 1e3:.1f} "
            f"| {ro['collective_s'] * 1e3:.1f} | {ro['dominant']} "
            f"| {ro['useful_ratio']:.2f} | {ro['roofline_fraction']:.4f} |")
    return hdr + "\n".join(rows) + "\n"


def run():
    data = load_records()
    for mesh_name, recs in data.items():
        print(f"roofline_table_{mesh_name},{len(recs)},cells")
    # write markdown fragment for EXPERIMENTS.md
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_tables.md", "w") as f:
        for mesh_name, recs in data.items():
            f.write(f"### {mesh_name}\n\n")
            f.write(fmt_table(recs))
            f.write("\n")
    print("roofline_tables_written,0,experiments/roofline_tables.md")


if __name__ == "__main__":
    run()
