"""Paper Figs. 16-18: multi-device scaling and the Amdahl fit.

Strong scaling measured on 8 spoofed host devices (subprocess), plus the
paper's Amdahl decomposition: the 2D external mode is the latency-bound
'serial' fraction, the 3D mode scales.  We report measured times for
1/2/4/8 ways and the fitted serial fraction; the dry-run collective model
extends the curve to 256/512 chips (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import json
import subprocess
import sys

from .common import row

_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time, json
import jax, jax.numpy as jnp
import numpy as np
from repro.core import geometry, mesh2d, stepper
from repro.distributed.ocean import DistributedOcean

results = {}
mesh2d_obj = mesh2d.rect_mesh(32, 16, 40e3, 20e3, jitter=0.15, seed=3)
b = np.full((3, mesh2d_obj.nt), 30.0, np.float32)
cfg = stepper.OceanConfig(nl=8, dt=20.0, m_2d=10, use_gls=True)
for p in (1, 2, 4, 8):
    dmesh = jax.make_mesh((p,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    do = DistributedOcean(mesh2d_obj, b, cfg, dmesh, ("data",))
    stk = do.init_state()
    step = do.make_step()
    stk = step(stk); jax.block_until_ready(stk)
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        stk = step(stk)
        jax.block_until_ready(stk)
        ts.append(time.perf_counter() - t0)
    results[p] = float(np.median(ts))
print("RESULTS=" + json.dumps(results))
'''


def run():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=3600, cwd="/root/repo",
        env={"PYTHONPATH": "src", "HOME": "/root", "PATH": "/usr/bin:/bin"})
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULTS=")]
    if not line:
        print("fig16_scaling,0,FAILED:" + res.stderr[-200:].replace(
            "\n", " "))
        return
    results = {int(k): v for k, v in json.loads(line[0][8:]).items()}
    t1 = results[1]
    # Amdahl fit: t(p) = t1*(s + (1-s)/p) — least squares over measured p
    import numpy as np
    ps = np.array(sorted(results))
    ts = np.array([results[p] for p in ps])
    A = np.stack([np.ones_like(ps, float), 1.0 / ps], axis=1)
    coef, *_ = np.linalg.lstsq(A, ts / t1, rcond=None)
    serial = max(min(coef[0], 1.0), 0.0)
    for p in ps:
        sp = t1 / results[p]
        eff = sp / p
        row(f"fig16_scaling_p{p}", results[p] * 1e6,
            f"speedup={sp:.2f};efficiency={eff:.2f}")
    row("fig16_amdahl_serial_fraction", serial * 1e6,
        f"serial_fraction={serial:.3f}")


if __name__ == "__main__":
    run()
