"""Paper Fig. 15: non-linear scaling with the number of vertical layers.

Two views:
  * measured CPU time per step per layer for nl in {1..32} (fixed 2D mesh) —
    the per-layer cost flattens once the column work amortises the 2D mode,
    mirroring the paper's curve shape;
  * the TPU cell-layout alignment model: the paper's dips at 16/32/64 layers
    come from block-size divisibility; our lane-layout analogue is sublane
    padding of the (nl*6, 128) cell tiles — occupancy = (nl*6)/ceil8(nl*6) —
    reported as the modelled efficiency factor per layer count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import geometry, mesh2d, stepper
from repro.core.extrusion import VGrid

from .common import row, time_fn

LAYERS = [1, 2, 4, 8, 12, 16, 24, 32]


def run():
    m = mesh2d.rect_mesh(12, 12, 10e3, 10e3, jitter=0.15, seed=2)
    geom = geometry.geom2d_from_mesh(m)
    b = jnp.full((3, m.nt), 30.0)
    for nl in LAYERS:
        vg = VGrid(b=b, nl=nl)
        cfg = stepper.OceanConfig(nl=nl, dt=20.0, m_2d=10, use_gls=True)
        st = stepper.init_state(geom, vg)
        step = jax.jit(lambda s, v=vg, c=cfg: stepper.step(geom, v, c, s))
        t = time_fn(step, st, warmup=1, iters=3)
        rows = nl * 6
        occupancy = rows / ((rows + 7) // 8 * 8)
        row(f"fig15_layers_nl{nl}", t * 1e6,
            f"us_per_layer={t * 1e6 / nl:.1f};"
            f"tpu_sublane_occupancy={occupancy:.3f}")


if __name__ == "__main__":
    run()
