"""Paper Fig. 13: single-device performance vs horizontal resolution.

Measures wall time per full 3D internal step on CPU for increasing mesh
sizes, reporting iteration time and DG-node throughput.  The paper's claim
reproduced in structure: near-linear scaling at large sizes with a constant
floor at small sizes (dispatch/latency-dominated — the CPU analogue of the
paper's kernel-launch floor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import geometry, mesh2d, stepper
from repro.core.extrusion import VGrid

from .common import row, time_fn

NL = 8
CASES = [(4, 4), (8, 8), (16, 16), (32, 32), (48, 48)]


def run():
    for nx, ny in CASES:
        m = mesh2d.rect_mesh(nx, ny, 10e3, 10e3, jitter=0.15, seed=1)
        geom = geometry.geom2d_from_mesh(m)
        b = jnp.full((3, m.nt), 30.0)
        vg = VGrid(b=b, nl=NL)
        cfg = stepper.OceanConfig(nl=NL, dt=20.0, m_2d=10, use_gls=True)
        st = stepper.init_state(geom, vg)
        eta = 0.02 * jnp.cos(jnp.pi * geom.node_x / 10e3)
        st = stepper.OceanState(
            ext=stepper.State2D(eta, st.ext.qx, st.ext.qy), ux=st.ux,
            uy=st.uy, T=st.T, S=st.S, turb_k=st.turb_k,
            turb_eps=st.turb_eps, nu_t=st.nu_t, kappa_t=st.kappa_t,
            time=st.time)
        step = jax.jit(lambda s: stepper.step(geom, vg, cfg, s))
        t = time_fn(step, st, warmup=1, iters=3)
        nodes = m.nt * NL * 6
        row(f"fig13_resolution_nt{m.nt}", t * 1e6,
            f"dg_nodes={nodes};nodes_per_s={nodes / t:.3e}")


if __name__ == "__main__":
    run()
