"""Horizontal-RHS pipeline: seed ref path vs fused caches vs Pallas kernel.

Assembles the per-stage horizontal bundle (pressure-gradient RHS, two
lateral flux speeds, momentum-prediction / momentum / tracer advdiff,
continuity RHS) three ways over nl in {4, 8, 16}:

  ref    — the seed call pattern: every call re-runs its own lateral int/ext
           gathers, zinterp and vol-quad interpolations (cache=None paths).
  fused  — one EdgeCache + two TransportCaches per stage, momentum+tracers
           batched into a single k=4 advdiff call (core/horizontal.py).
  pallas — fused caches + the lateral advective term through the
           kernels/horizontal_flux.py cell-layout kernel (interpreted on
           CPU, compiled on TPU).

Rows: name,us_per_call,derived.  Also writes BENCH_horizontal.json (list of
row dicts incl. speedup and max|fused-ref|) so the perf trajectory of the
model's hottest loop is machine-readable from this PR onward.

Observability additions (obs/):
  * every timing row carries p50/p90 spread (common.Timing) and the
    roofline view from the compiled HLO — modelled bytes, achieved vs
    platform-bound bandwidth (`roofline.analysis.peak_bandwidth`),
  * `--trace` wraps the run in `obs.trace.trace_session` (profile lands in
    the run dir),
  * a per-component nl=16 seed-vs-fused breakdown (kind="breakdown" rows)
    records WHERE the fused pipeline wins — diagnosis artifact only.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dg3d, geometry, horizontal, mesh2d
from repro.core.extrusion import VGrid, layer_geometry
from repro.kernels import dispatch
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.roofline import analysis as roofline

from .common import row, time_fn

LAYERS = [4, 8, 16]


def _setup(nl, nx=24, ny=18):
    """Channel mesh (interior + WALL + OPEN edges) with smooth active flow."""
    m = mesh2d.channel_mesh(nx, ny, 8000.0, 6000.0, jitter=0.15, seed=7)
    geom = geometry.geom2d_from_mesh(m)
    dt = geom.area.dtype
    nt = m.nt
    b = jnp.full((3, nt), 20.0, dt)
    vg = VGrid(b=b, nl=nl)
    eta = (0.05 * jnp.cos(jnp.pi * geom.node_x / 8000.0)
           * jnp.cos(jnp.pi * geom.node_y / 6000.0)).astype(dt)
    vge = layer_geometry(vg, eta)
    rng = np.random.default_rng(0)
    r3 = lambda s=0.05: jnp.asarray(
        rng.normal(scale=s, size=(nl, 6, nt)).astype(dt))
    ux = 0.1 + r3()
    uy = r3()
    T = 10.0 + r3(0.5)
    S = 35.0 + r3(0.5)
    rho = -0.15 * (T - 10.0)
    return geom, vg, vge, eta, ux, uy, T, S, rho


# ---------------------------------------------------------------------------
# The SEED implementation, copied verbatim (PR-1 state): per-edge .at[].add
# edge scatter and the monolithic advdiff that re-runs every interpolation.
# This is the wall-clock baseline the fused pipeline is measured against —
# the refactored no-cache path in dg3d shares code (and micro-optimisations)
# with the fused path, so it is the *numerical* oracle but not the perf seed.
# ---------------------------------------------------------------------------
def _seed_edge_scatter(geom, g):
    import numpy as np
    from repro.core.geometry import EDGE_A, EDGE_B, W_GAUSS, _PHIA, _PHIB
    w = geom.edge_len[:, None, :] * jnp.asarray(W_GAUSS)[:, None]
    ga = (g * w * _PHIA[:, None]).sum(axis=-2)
    gb = (g * w * _PHIB[:, None]).sum(axis=-2)
    out = jnp.zeros_like(ga)
    for e in range(3):
        out = out.at[..., EDGE_A[e], :].add(ga[..., e, :])
        out = out.at[..., EDGE_B[e], :].add(gb[..., e, :])
    return out


def _seed_lat_scatter(geom, g):
    from repro.core.vertical import PHI_Z
    s = _seed_edge_scatter(geom, g)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    return jnp.concatenate([top, bot], axis=-2)


def _seed_advdiff(geom, vge, nl, f, qx, qy, flux, nu_h, bc_reflect=False):
    from repro.core import geometry as G
    from repro.core.dg3d import (_gather_ext_grad, iso_grad, lat_interp,
                                 lat_interp_ext, reflect_pair,
                                 sigma3_lateral, zinterp)
    from repro.core.vertical import PHI_Z
    k = f.shape[0]
    jz_q = G.vol_interp(vge.jz)
    fq = zinterp(f)
    fqq = G.vol_interp(fq)
    qxq = G.vol_interp(zinterp(qx))
    qyq = G.vol_interp(zinterp(qy))
    gx = (fqq * qxq).sum(axis=-2)
    gy = (fqq * qyq).sum(axis=-2)
    sx = gx[..., None, :] * geom.dphi[:, 0, :]
    sy = gy[..., None, :] * geom.dphi[:, 1, :]
    s = (sx + sy) * (geom.area / 3.0)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    out = jnp.concatenate([top, bot], axis=-2)
    fi = lat_interp(f)
    fe = lat_interp_ext(geom, f)
    if bc_reflect:
        fxe, fye = reflect_pair(geom, fe[0], fe[1])
        fe = jnp.stack([fxe, fye])
    f_up = jnp.where(flux.upwind > 0.5, fi, fe)
    out = out - _seed_lat_scatter(geom, f_up * flux.speed[None])
    nu_q = G.vol_interp(zinterp(nu_h))
    gradf = iso_grad(geom, fq)
    coef = (nu_q * jz_q).sum(axis=-2) / 3.0 * geom.area
    dvol = jnp.einsum("...zdt,ndt,...zt->...znt", gradf, geom.dphi, coef)
    dtop = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], dvol)
    dbot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], dvol)
    out = out - jnp.concatenate([dtop, dbot], axis=-2)
    gno = jnp.einsum("...zdt,edt->...zet", gradf,
                     jnp.stack([geom.edge_nx, geom.edge_ny], axis=1))
    nzjz_int = G.edge_interp(vge.jz)
    nu_int = lat_interp(nu_h)
    flux_int = gno[..., None, :] * nu_int[None] * nzjz_int[None, None, None]
    gradf_e = _gather_ext_grad(geom, gradf)
    nzjz_ext = G.edge_interp_ext(geom, vge.jz)
    nu_ext = lat_interp_ext(geom, nu_h)
    flux_ext = gradf_e[..., None, :] * nu_ext[None] * nzjz_ext[None, None, None]
    interior = geom.interior[None, :, None, :]
    mean_flux = 0.5 * (flux_int + flux_ext) * interior
    out = out + _seed_lat_scatter(geom, mean_flux)
    sig = sigma3_lateral(geom)
    numean = 0.5 * (nu_int + nu_ext)
    jzmean = 0.5 * (nzjz_int + nzjz_ext)
    jumpf = 0.5 * (fi - fe)
    pen = sig[:, None, :] * numean * jzmean[None, None] * jumpf * interior
    out = out - _seed_lat_scatter(geom, pen)
    return out


def _seed_continuity(geom, vge, nl, qx, qy, flux):
    from repro.core import geometry as G
    from repro.core.dg3d import zinterp
    from repro.core.vertical import PHI_Z
    qxq = G.vol_interp(zinterp(qx))
    qyq = G.vol_interp(zinterp(qy))
    sx = jnp.einsum("...zqt,nt->...znt", qxq, geom.dphi[:, 0, :])
    sy = jnp.einsum("...zqt,nt->...znt", qyq, geom.dphi[:, 1, :])
    s = (sx + sy) * (geom.area / 3.0)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    F = jnp.concatenate([top, bot], axis=-2)
    return F - _seed_lat_scatter(geom, flux.speed)


def rhs_ref(geom, vg, vge, nl, ux, uy, T, S, eta, rho):
    """The seed per-call pattern: 2 flux speeds + 3 monolithic advdiff
    calls, every one re-interpolating jz / transport / neighbour states.
    As in the real stage, the prediction transport (q) and the corrected
    transport (q-bar) differ, so the two flux/advection chains are
    genuinely distinct work."""
    q = dg3d.transport_from_velocity(vge, ux, uy)
    qbx, qby = _corrected_transport(q, nl)
    nu_h = dg3d.smagorinsky_nu(geom, ux, uy)
    kap_h = dg3d.okubo_kappa(geom, nl)
    u_pair = jnp.stack([ux, uy])
    tr_pair = jnp.stack([T, S])
    flux1 = dg3d.lateral_flux_speed(geom, vge, vg, q[0], q[1], eta, vg.b)
    f_pred = _seed_advdiff(geom, vge, nl, u_pair, q[0], q[1],
                           flux1, nu_h, bc_reflect=True)
    flux2 = dg3d.lateral_flux_speed(geom, vge, vg, qbx, qby, eta, vg.b)
    f_mom = _seed_advdiff(geom, vge, nl, u_pair, qbx, qby,
                          flux2, nu_h, bc_reflect=True)
    f_tr = _seed_advdiff(geom, vge, nl, tr_pair, qbx, qby,
                         flux2, kap_h, bc_reflect=False)
    F_cont = _seed_continuity(geom, vge, nl, qbx, qby, flux2)
    F_r, r_s = dg3d.pressure_gradient_rhs(geom, vg, vge, rho)
    return f_pred, f_mom, f_tr, F_cont, F_r, r_s


def _corrected_transport(q, nl):
    """A q-bar-like column-wise corrected transport (mirrors the stage's
    consistent_transport defect distribution without running the 2D burst)."""
    from repro.core.extrusion import vsum_dofs
    d = vsum_dofs(q[0]) / (2.0 * nl)
    d6 = jnp.concatenate([d, d], axis=-2)
    return q[0] + 0.01 * d6[None], q[1] - 0.01 * d6[None]


def rhs_fused(geom, vg, vge, nl, ux, uy, T, S, eta, rho, backend="ref"):
    """The fused pipeline: one EdgeCache, shared TransportCaches, batched
    momentum+tracer advdiff, optional Pallas lateral-flux kernel."""
    hc = horizontal.stage_cache(geom, vge)
    q = dg3d.transport_from_velocity(vge, ux, uy)
    qbx, qby = _corrected_transport(q, nl)
    nu_h = dg3d.smagorinsky_nu(geom, ux, uy)
    kap_h = dg3d.okubo_kappa(geom, nl)
    u_pair = jnp.stack([ux, uy])
    tr_pair = jnp.stack([T, S])
    fs_u = dg3d.field_states(geom, u_pair, bc_reflect=True)
    diff_u = dg3d.horizontal_diffusion(geom, vge, nl, u_pair, nu_h,
                                       cache=hc, fcache=fs_u)
    tc1 = horizontal.transport_cache(geom, vge, vg, hc, q[0], q[1])
    f_pred = dg3d.horizontal_advection(geom, vge, nl, u_pair, q[0], q[1],
                                       tc1.flux, tcache=tc1, fcache=fs_u,
                                       backend=backend) + diff_u
    tc2 = horizontal.transport_cache(geom, vge, vg, hc, qbx, qby)
    f_mom, f_tr = horizontal.advdiff_momentum_tracers(
        geom, vge, nl, u_pair, tr_pair, qbx, qby, tc2.flux, nu_h, kap_h,
        fs_u=fs_u, diff_u=diff_u, cache=hc, tcache=tc2, backend=backend)
    F_cont = dg3d.continuity_rhs(geom, vge, nl, qbx, qby, tc2.flux,
                                 tcache=tc2)
    F_r, r_s = dg3d.pressure_gradient_rhs(geom, vg, vge, rho, cache=hc)
    return f_pred, f_mom, f_tr, F_cont, F_r, r_s


def _maxdiff(a, b):
    """Max relative difference over the bundle (scaled per output)."""
    return max(float(jnp.abs(x - y).max())
               / max(float(jnp.abs(x).max()), 1e-30) for x, y in zip(a, b))


def _hlo_bytes(jitted, *args):
    """Modelled HBM/host-memory traffic (bytes) of the compiled program."""
    try:
        compiled = jitted.lower(*args).compile()
        return float(roofline.analyze_hlo_text(compiled.as_text()).bytes)
    except Exception:
        return None


def _roofline_fields(t, nbytes, bound):
    """achieved-vs-bound bandwidth fields for one timing row."""
    if nbytes is None or t <= 0:
        return dict(hlo_bytes=None, achieved_gbps=None,
                    bound_gbps=bound / 1e9, roofline_frac=None)
    achieved = nbytes / float(t)
    return dict(hlo_bytes=nbytes, achieved_gbps=achieved / 1e9,
                bound_gbps=bound / 1e9, roofline_frac=achieved / bound)


def _breakdown(nl, warmup, iters, bound):
    """Per-component seed-vs-fused timing at one layer count.

    Diagnosis artifact only: upstream values (caches, flux speeds, field
    states) are precomputed and passed as runtime arguments, so each row
    isolates ONE pipeline component."""
    geom, vg, vge, eta, ux, uy, T, S, rho = _setup(nl)
    nt = geom.nt
    q = jax.jit(dg3d.transport_from_velocity)(vge, ux, uy)
    qbx, qby = _corrected_transport(q, nl)
    nu_h = jax.jit(dg3d.smagorinsky_nu)(geom, ux, uy)
    kap_h = dg3d.okubo_kappa(geom, nl)
    u_pair = jnp.stack([ux, uy])
    tr_pair = jnp.stack([T, S])
    jfs = jax.jit(lambda u: dg3d.field_states(geom, u, bc_reflect=True))
    fs_u = jfs(u_pair)
    jhc = jax.jit(lambda e: horizontal.stage_cache(geom, e))
    hc = jhc(vge)
    jtc = jax.jit(lambda e, c, qx, qy:
                  horizontal.transport_cache(geom, e, vg, c, qx, qy))
    tc1 = jtc(vge, hc, q[0], q[1])
    tc2 = jtc(vge, hc, qbx, qby)
    jflux = jax.jit(lambda e, qx, qy, et:
                    dg3d.lateral_flux_speed(geom, e, vg, qx, qy, et, vg.b))
    flux1 = jflux(vge, q[0], q[1], eta)
    flux2 = jflux(vge, qbx, qby, eta)
    jdiff = jax.jit(lambda e, u, nu, c, fs: dg3d.horizontal_diffusion(
        geom, e, nl, u, nu, cache=c, fcache=fs))
    diff_u = jdiff(vge, u_pair, nu_h, hc, fs_u)

    comps = [
        ("seed", "flux_speed",
         jflux, (vge, qbx, qby, eta)),
        ("seed", "advdiff_pred",
         jax.jit(lambda e, u, qx, qy, fl, nu: _seed_advdiff(
             geom, e, nl, u, qx, qy, fl, nu, bc_reflect=True)),
         (vge, u_pair, q[0], q[1], flux1, nu_h)),
        ("seed", "advdiff_mom",
         jax.jit(lambda e, u, qx, qy, fl, nu: _seed_advdiff(
             geom, e, nl, u, qx, qy, fl, nu, bc_reflect=True)),
         (vge, u_pair, qbx, qby, flux2, nu_h)),
        ("seed", "advdiff_tracers",
         jax.jit(lambda e, f, qx, qy, fl, kp: _seed_advdiff(
             geom, e, nl, f, qx, qy, fl, kp, bc_reflect=False)),
         (vge, tr_pair, qbx, qby, flux2, kap_h)),
        ("seed", "continuity",
         jax.jit(lambda e, qx, qy, fl: _seed_continuity(
             geom, e, nl, qx, qy, fl)),
         (vge, qbx, qby, flux2)),
        ("seed", "pressure_grad",
         jax.jit(lambda e, r: dg3d.pressure_gradient_rhs(geom, vg, e, r)),
         (vge, rho)),
        ("fused", "stage_cache", jhc, (vge,)),
        ("fused", "field_states", jfs, (u_pair,)),
        ("fused", "transport_caches",
         jax.jit(lambda e, c, qx, qy, qbx_, qby_: (
             horizontal.transport_cache(geom, e, vg, c, qx, qy),
             horizontal.transport_cache(geom, e, vg, c, qbx_, qby_))),
         (vge, hc, q[0], q[1], qbx, qby)),
        ("fused", "diffusion", jdiff, (vge, u_pair, nu_h, hc, fs_u)),
        ("fused", "advection_pred",
         jax.jit(lambda e, u, qx, qy, tc, fs: dg3d.horizontal_advection(
             geom, e, nl, u, qx, qy, tc.flux, tcache=tc, fcache=fs,
             backend="ref")),
         (vge, u_pair, q[0], q[1], tc1, fs_u)),
        ("fused", "advdiff_mom_tracers",
         jax.jit(lambda e, u, tr, qx, qy, tc, fs, du, c:
                 horizontal.advdiff_momentum_tracers(
                     geom, e, nl, u, tr, qx, qy, tc.flux, nu_h, kap_h,
                     fs_u=fs, diff_u=du, cache=c, tcache=tc, backend="ref")),
         (vge, u_pair, tr_pair, qbx, qby, tc2, fs_u, diff_u, hc)),
        ("fused", "continuity",
         jax.jit(lambda e, qx, qy, tc: dg3d.continuity_rhs(
             geom, e, nl, qx, qy, tc.flux, tcache=tc)),
         (vge, qbx, qby, tc2)),
        ("fused", "pressure_grad",
         jax.jit(lambda e, r, c: dg3d.pressure_gradient_rhs(
             geom, vg, e, r, cache=c)),
         (vge, rho, hc)),
    ]
    records = []
    for path, comp, fn, fargs in comps:
        t = time_fn(fn, *fargs, warmup=warmup, iters=iters, reduce="min")
        rec = dict(kind="breakdown", path=path, component=comp, nl=nl, nt=nt,
                   us_per_call=t * 1e6, p50_us=t.p50 * 1e6,
                   p90_us=t.p90 * 1e6)
        rec.update(_roofline_fields(t, _hlo_bytes(fn, *fargs), bound))
        row(f"breakdown_nl{nl}_{path}_{comp}", t * 1e6, "")
        records.append(rec)
    return records


def run(layers=LAYERS, json_path="BENCH_horizontal.json", dry_run=False,
        warmup=3, iters=9, breakdown_nl=16, trace=False):
    interp = dispatch.interpret_default()
    kmode = "interpret" if interp else "compiled"
    kbackend = "pallas_interpret" if interp else "pallas"
    bound = roofline.peak_bandwidth()
    reg = obs_metrics.default()
    if dry_run:
        # compile/shape smoke only: tiny mesh, one iteration, no JSON (do
        # not clobber a real perf record with smoke numbers)
        layers, warmup, iters, json_path = [layers[0]], 1, 1, None
        breakdown_nl = None
    records = []
    with obs_trace.trace_session(enabled=trace) as run_dir:
        if run_dir:
            print(f"# profile -> {run_dir}", flush=True)
        for nl in layers:
            geom, vg, vge, eta, ux, uy, T, S, rho = _setup(
                nl, nx=8 if dry_run else 24, ny=6 if dry_run else 18)
            nt = geom.nt
            args = (ux, uy, T, S, eta, rho)
            f_ref = jax.jit(lambda *a, g=geom, v=vg, e=vge, n=nl:
                            rhs_ref(g, v, e, n, *a))
            f_fus = jax.jit(lambda *a, g=geom, v=vg, e=vge, n=nl:
                            rhs_fused(g, v, e, n, *a, backend="ref"))
            f_pal = jax.jit(lambda *a, g=geom, v=vg, e=vge, n=nl:
                            rhs_fused(g, v, e, n, *a, backend=kbackend))
            out_ref = f_ref(*args)
            diff_fus = _maxdiff(out_ref, f_fus(*args))
            diff_pal = _maxdiff(out_ref, f_pal(*args))
            t_ref = time_fn(f_ref, *args, warmup=warmup, iters=iters,
                            reduce="min")
            t_fus = time_fn(f_fus, *args, warmup=warmup, iters=iters,
                            reduce="min")
            t_pal = time_fn(f_pal, *args, warmup=warmup, iters=iters,
                            reduce="min")
            bytes_by = {"ref": _hlo_bytes(f_ref, *args),
                        "fused": _hlo_bytes(f_fus, *args),
                        f"pallas_{kmode}": _hlo_bytes(f_pal, *args)}
            for name, t, diff, extra in (
                    ("ref", t_ref, 0.0, ""),
                    ("fused", t_fus, diff_fus,
                     f"speedup_vs_ref={t_ref / t_fus:.2f}x"),
                    (f"pallas_{kmode}", t_pal, diff_pal,
                     f"speedup_vs_ref={t_ref / t_pal:.2f}x")):
                derived = f"maxdiff={diff:.2e}" + (f";{extra}" if extra
                                                   else "")
                row(f"horizontal_rhs_nl{nl}_nt{nt}_{name}", t * 1e6, derived)
                rec = dict(name=name, nl=nl, nt=nt,
                           us_per_call=t * 1e6,
                           p50_us=t.p50 * 1e6, p90_us=t.p90 * 1e6,
                           speedup_vs_ref=t_ref / t,
                           maxdiff_vs_ref=diff)
                rec.update(_roofline_fields(t, bytes_by[name], bound))
                records.append(rec)
                reg.event("bench.horizontal_rhs", rec)
        if breakdown_nl:
            records += _breakdown(breakdown_nl, warmup, iters, bound)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(records, fh, indent=2)
    return records


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny mesh, 1 iter: compile/shape smoke for CI")
    ap.add_argument("--trace", action="store_true",
                    help="wrap the run in a jax.profiler trace session")
    ap.add_argument("--json", default="BENCH_horizontal.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json, dry_run=args.dry_run, trace=args.trace)
