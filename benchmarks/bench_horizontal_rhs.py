"""Horizontal-RHS pipeline: seed ref path vs fused caches vs Pallas kernel.

Assembles the per-stage horizontal bundle (pressure-gradient RHS, two
lateral flux speeds, momentum-prediction / momentum / tracer advdiff,
continuity RHS) three ways over nl in {4, 8, 16}:

  ref    — the seed call pattern: every call re-runs its own lateral int/ext
           gathers, zinterp and vol-quad interpolations (cache=None paths).
  fused  — one EdgeCache + two TransportCaches per stage, momentum+tracers
           batched into a single k=4 advdiff call (core/horizontal.py).
  pallas — fused caches + the lateral advective term through the
           kernels/horizontal_flux.py cell-layout kernel (interpreted on
           CPU, compiled on TPU).

Rows: name,us_per_call,derived.  Also writes BENCH_horizontal.json (list of
row dicts incl. speedup and max|fused-ref|) so the perf trajectory of the
model's hottest loop is machine-readable from this PR onward.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dg3d, geometry, horizontal, mesh2d
from repro.core.extrusion import VGrid, layer_geometry
from repro.kernels import dispatch

from .common import row, time_fn

LAYERS = [4, 8, 16]


def _setup(nl, nx=24, ny=18):
    """Channel mesh (interior + WALL + OPEN edges) with smooth active flow."""
    m = mesh2d.channel_mesh(nx, ny, 8000.0, 6000.0, jitter=0.15, seed=7)
    geom = geometry.geom2d_from_mesh(m)
    dt = geom.area.dtype
    nt = m.nt
    b = jnp.full((3, nt), 20.0, dt)
    vg = VGrid(b=b, nl=nl)
    eta = (0.05 * jnp.cos(jnp.pi * geom.node_x / 8000.0)
           * jnp.cos(jnp.pi * geom.node_y / 6000.0)).astype(dt)
    vge = layer_geometry(vg, eta)
    rng = np.random.default_rng(0)
    r3 = lambda s=0.05: jnp.asarray(
        rng.normal(scale=s, size=(nl, 6, nt)).astype(dt))
    ux = 0.1 + r3()
    uy = r3()
    T = 10.0 + r3(0.5)
    S = 35.0 + r3(0.5)
    rho = -0.15 * (T - 10.0)
    return geom, vg, vge, eta, ux, uy, T, S, rho


# ---------------------------------------------------------------------------
# The SEED implementation, copied verbatim (PR-1 state): per-edge .at[].add
# edge scatter and the monolithic advdiff that re-runs every interpolation.
# This is the wall-clock baseline the fused pipeline is measured against —
# the refactored no-cache path in dg3d shares code (and micro-optimisations)
# with the fused path, so it is the *numerical* oracle but not the perf seed.
# ---------------------------------------------------------------------------
def _seed_edge_scatter(geom, g):
    import numpy as np
    from repro.core.geometry import EDGE_A, EDGE_B, W_GAUSS, _PHIA, _PHIB
    w = geom.edge_len[:, None, :] * jnp.asarray(W_GAUSS)[:, None]
    ga = (g * w * _PHIA[:, None]).sum(axis=-2)
    gb = (g * w * _PHIB[:, None]).sum(axis=-2)
    out = jnp.zeros_like(ga)
    for e in range(3):
        out = out.at[..., EDGE_A[e], :].add(ga[..., e, :])
        out = out.at[..., EDGE_B[e], :].add(gb[..., e, :])
    return out


def _seed_lat_scatter(geom, g):
    from repro.core.vertical import PHI_Z
    s = _seed_edge_scatter(geom, g)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    return jnp.concatenate([top, bot], axis=-2)


def _seed_advdiff(geom, vge, nl, f, qx, qy, flux, nu_h, bc_reflect=False):
    from repro.core import geometry as G
    from repro.core.dg3d import (_gather_ext_grad, iso_grad, lat_interp,
                                 lat_interp_ext, reflect_pair,
                                 sigma3_lateral, zinterp)
    from repro.core.vertical import PHI_Z
    k = f.shape[0]
    jz_q = G.vol_interp(vge.jz)
    fq = zinterp(f)
    fqq = G.vol_interp(fq)
    qxq = G.vol_interp(zinterp(qx))
    qyq = G.vol_interp(zinterp(qy))
    gx = (fqq * qxq).sum(axis=-2)
    gy = (fqq * qyq).sum(axis=-2)
    sx = gx[..., None, :] * geom.dphi[:, 0, :]
    sy = gy[..., None, :] * geom.dphi[:, 1, :]
    s = (sx + sy) * (geom.area / 3.0)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    out = jnp.concatenate([top, bot], axis=-2)
    fi = lat_interp(f)
    fe = lat_interp_ext(geom, f)
    if bc_reflect:
        fxe, fye = reflect_pair(geom, fe[0], fe[1])
        fe = jnp.stack([fxe, fye])
    f_up = jnp.where(flux.upwind > 0.5, fi, fe)
    out = out - _seed_lat_scatter(geom, f_up * flux.speed[None])
    nu_q = G.vol_interp(zinterp(nu_h))
    gradf = iso_grad(geom, fq)
    coef = (nu_q * jz_q).sum(axis=-2) / 3.0 * geom.area
    dvol = jnp.einsum("...zdt,ndt,...zt->...znt", gradf, geom.dphi, coef)
    dtop = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], dvol)
    dbot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], dvol)
    out = out - jnp.concatenate([dtop, dbot], axis=-2)
    gno = jnp.einsum("...zdt,edt->...zet", gradf,
                     jnp.stack([geom.edge_nx, geom.edge_ny], axis=1))
    nzjz_int = G.edge_interp(vge.jz)
    nu_int = lat_interp(nu_h)
    flux_int = gno[..., None, :] * nu_int[None] * nzjz_int[None, None, None]
    gradf_e = _gather_ext_grad(geom, gradf)
    nzjz_ext = G.edge_interp_ext(geom, vge.jz)
    nu_ext = lat_interp_ext(geom, nu_h)
    flux_ext = gradf_e[..., None, :] * nu_ext[None] * nzjz_ext[None, None, None]
    interior = geom.interior[None, :, None, :]
    mean_flux = 0.5 * (flux_int + flux_ext) * interior
    out = out + _seed_lat_scatter(geom, mean_flux)
    sig = sigma3_lateral(geom)
    numean = 0.5 * (nu_int + nu_ext)
    jzmean = 0.5 * (nzjz_int + nzjz_ext)
    jumpf = 0.5 * (fi - fe)
    pen = sig[:, None, :] * numean * jzmean[None, None] * jumpf * interior
    out = out - _seed_lat_scatter(geom, pen)
    return out


def _seed_continuity(geom, vge, nl, qx, qy, flux):
    from repro.core import geometry as G
    from repro.core.dg3d import zinterp
    from repro.core.vertical import PHI_Z
    qxq = G.vol_interp(zinterp(qx))
    qyq = G.vol_interp(zinterp(qy))
    sx = jnp.einsum("...zqt,nt->...znt", qxq, geom.dphi[:, 0, :])
    sy = jnp.einsum("...zqt,nt->...znt", qyq, geom.dphi[:, 1, :])
    s = (sx + sy) * (geom.area / 3.0)
    top = jnp.einsum("z,...znt->...nt", PHI_Z[:, 0], s)
    bot = jnp.einsum("z,...znt->...nt", PHI_Z[:, 1], s)
    F = jnp.concatenate([top, bot], axis=-2)
    return F - _seed_lat_scatter(geom, flux.speed)


def rhs_ref(geom, vg, vge, nl, ux, uy, T, S, eta, rho):
    """The seed per-call pattern: 2 flux speeds + 3 monolithic advdiff
    calls, every one re-interpolating jz / transport / neighbour states.
    As in the real stage, the prediction transport (q) and the corrected
    transport (q-bar) differ, so the two flux/advection chains are
    genuinely distinct work."""
    q = dg3d.transport_from_velocity(vge, ux, uy)
    qbx, qby = _corrected_transport(q, nl)
    nu_h = dg3d.smagorinsky_nu(geom, ux, uy)
    kap_h = dg3d.okubo_kappa(geom, nl)
    u_pair = jnp.stack([ux, uy])
    tr_pair = jnp.stack([T, S])
    flux1 = dg3d.lateral_flux_speed(geom, vge, vg, q[0], q[1], eta, vg.b)
    f_pred = _seed_advdiff(geom, vge, nl, u_pair, q[0], q[1],
                           flux1, nu_h, bc_reflect=True)
    flux2 = dg3d.lateral_flux_speed(geom, vge, vg, qbx, qby, eta, vg.b)
    f_mom = _seed_advdiff(geom, vge, nl, u_pair, qbx, qby,
                          flux2, nu_h, bc_reflect=True)
    f_tr = _seed_advdiff(geom, vge, nl, tr_pair, qbx, qby,
                         flux2, kap_h, bc_reflect=False)
    F_cont = _seed_continuity(geom, vge, nl, qbx, qby, flux2)
    F_r, r_s = dg3d.pressure_gradient_rhs(geom, vg, vge, rho)
    return f_pred, f_mom, f_tr, F_cont, F_r, r_s


def _corrected_transport(q, nl):
    """A q-bar-like column-wise corrected transport (mirrors the stage's
    consistent_transport defect distribution without running the 2D burst)."""
    from repro.core.extrusion import vsum_dofs
    d = vsum_dofs(q[0]) / (2.0 * nl)
    d6 = jnp.concatenate([d, d], axis=-2)
    return q[0] + 0.01 * d6[None], q[1] - 0.01 * d6[None]


def rhs_fused(geom, vg, vge, nl, ux, uy, T, S, eta, rho, backend="ref"):
    """The fused pipeline: one EdgeCache, shared TransportCaches, batched
    momentum+tracer advdiff, optional Pallas lateral-flux kernel."""
    hc = horizontal.stage_cache(geom, vge)
    q = dg3d.transport_from_velocity(vge, ux, uy)
    qbx, qby = _corrected_transport(q, nl)
    nu_h = dg3d.smagorinsky_nu(geom, ux, uy)
    kap_h = dg3d.okubo_kappa(geom, nl)
    u_pair = jnp.stack([ux, uy])
    tr_pair = jnp.stack([T, S])
    fs_u = dg3d.field_states(geom, u_pair, bc_reflect=True)
    diff_u = dg3d.horizontal_diffusion(geom, vge, nl, u_pair, nu_h,
                                       cache=hc, fcache=fs_u)
    tc1 = horizontal.transport_cache(geom, vge, vg, hc, q[0], q[1])
    f_pred = dg3d.horizontal_advection(geom, vge, nl, u_pair, q[0], q[1],
                                       tc1.flux, tcache=tc1, fcache=fs_u,
                                       backend=backend) + diff_u
    tc2 = horizontal.transport_cache(geom, vge, vg, hc, qbx, qby)
    f_mom, f_tr = horizontal.advdiff_momentum_tracers(
        geom, vge, nl, u_pair, tr_pair, qbx, qby, tc2.flux, nu_h, kap_h,
        fs_u=fs_u, diff_u=diff_u, cache=hc, tcache=tc2, backend=backend)
    F_cont = dg3d.continuity_rhs(geom, vge, nl, qbx, qby, tc2.flux,
                                 tcache=tc2)
    F_r, r_s = dg3d.pressure_gradient_rhs(geom, vg, vge, rho, cache=hc)
    return f_pred, f_mom, f_tr, F_cont, F_r, r_s


def _maxdiff(a, b):
    """Max relative difference over the bundle (scaled per output)."""
    return max(float(jnp.abs(x - y).max())
               / max(float(jnp.abs(x).max()), 1e-30) for x, y in zip(a, b))


def run(layers=LAYERS, json_path="BENCH_horizontal.json", dry_run=False,
        warmup=3, iters=9):
    interp = dispatch.interpret_default()
    kmode = "interpret" if interp else "compiled"
    kbackend = "pallas_interpret" if interp else "pallas"
    if dry_run:
        # compile/shape smoke only: tiny mesh, one iteration, no JSON (do
        # not clobber a real perf record with smoke numbers)
        layers, warmup, iters, json_path = [layers[0]], 1, 1, None
    records = []
    for nl in layers:
        geom, vg, vge, eta, ux, uy, T, S, rho = _setup(
            nl, nx=8 if dry_run else 24, ny=6 if dry_run else 18)
        nt = geom.nt
        args = (ux, uy, T, S, eta, rho)
        f_ref = jax.jit(lambda *a, g=geom, v=vg, e=vge, n=nl:
                        rhs_ref(g, v, e, n, *a))
        f_fus = jax.jit(lambda *a, g=geom, v=vg, e=vge, n=nl:
                        rhs_fused(g, v, e, n, *a, backend="ref"))
        f_pal = jax.jit(lambda *a, g=geom, v=vg, e=vge, n=nl:
                        rhs_fused(g, v, e, n, *a, backend=kbackend))
        out_ref = f_ref(*args)
        diff_fus = _maxdiff(out_ref, f_fus(*args))
        diff_pal = _maxdiff(out_ref, f_pal(*args))
        t_ref = time_fn(f_ref, *args, warmup=warmup, iters=iters, reduce="min")
        t_fus = time_fn(f_fus, *args, warmup=warmup, iters=iters, reduce="min")
        t_pal = time_fn(f_pal, *args, warmup=warmup, iters=iters, reduce="min")
        for name, t, diff, extra in (
                ("ref", t_ref, 0.0, ""),
                ("fused", t_fus, diff_fus,
                 f"speedup_vs_ref={t_ref / t_fus:.2f}x"),
                (f"pallas_{kmode}", t_pal, diff_pal,
                 f"speedup_vs_ref={t_ref / t_pal:.2f}x")):
            derived = f"maxdiff={diff:.2e}" + (f";{extra}" if extra else "")
            row(f"horizontal_rhs_nl{nl}_nt{nt}_{name}", t * 1e6, derived)
            records.append(dict(name=name, nl=nl, nt=nt,
                                us_per_call=t * 1e6,
                                speedup_vs_ref=t_ref / t,
                                maxdiff_vs_ref=diff))
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(records, fh, indent=2)
    return records


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny mesh, 1 iter: compile/shape smoke for CI")
    ap.add_argument("--json", default="BENCH_horizontal.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json, dry_run=args.dry_run)
