"""Flight-recorder report CLI: summarise runs, diff bench artifacts.

    python -m benchmarks.obs_report summary  runs/obs/metrics.jsonl
    python -m benchmarks.obs_report validate runs/obs/metrics.jsonl
    python -m benchmarks.obs_report diff     old/BENCH_horizontal.json \
                                             new/BENCH_horizontal.json \
                                             [--fail --threshold 0.10]

`summary` renders a JSONL metrics stream (kind-aware: counters/gauges as
tables, histogram p50/p90, last physics diagnostics, monitor violations).
`diff` matches bench rows on their identity fields (name/nl/nt or
path/component) and reports the per-row time ratio; with `--fail`, any row
slower than (1 + threshold)x the baseline exits non-zero — a perf gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

from repro.obs import schema


# ---------------------------------------------------------------------------
# summary
# ---------------------------------------------------------------------------
def _load_jsonl(path: str) -> List[dict]:
    recs = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    return recs


def _fmt_labels(rec: dict) -> str:
    lbl = rec.get("labels") or {}
    if not lbl:
        return rec["name"]
    inner = ",".join(f"{k}={v}" for k, v in sorted(lbl.items()))
    return f"{rec['name']}{{{inner}}}"


def summary(path: str, out=sys.stdout) -> int:
    recs = _load_jsonl(path)
    by_kind: Dict[str, List[dict]] = {}
    for r in recs:
        by_kind.setdefault(r.get("kind", "?"), []).append(r)
    print(f"# {path}: {len(recs)} records", file=out)
    # counters/gauges: last value per instrument
    for kind in ("counter", "gauge"):
        last: Dict[str, Any] = {}
        for r in by_kind.get(kind, []):
            last[_fmt_labels(r)] = r.get("value")
        if last:
            print(f"\n[{kind}s]", file=out)
            for k in sorted(last):
                print(f"  {k} = {last[k]}", file=out)
    hists: Dict[str, dict] = {}
    for r in by_kind.get("histogram", []):
        hists[_fmt_labels(r)] = r.get("value") or {}
    if hists:
        print("\n[histograms]", file=out)
        for k in sorted(hists):
            v = hists[k]
            print(f"  {k}: n={v.get('count')} p50={v.get('p50'):.6g} "
                  f"p90={v.get('p90'):.6g} max={v.get('max'):.6g}", file=out)
    diags = by_kind.get("diagnostics", [])
    if diags:
        d = diags[-1]
        print(f"\n[diagnostics] last @ step {d.get('step')}:", file=out)
        for k, v in sorted((d.get("value") or {}).items()):
            print(f"  {k} = {v}", file=out)
    events = by_kind.get("event", [])
    viols = [e for e in events if e["name"] == "monitor.violation"]
    if events:
        print(f"\n[events] {len(events)} total, "
              f"{len(viols)} monitor violations", file=out)
        for e in viols:
            print(f"  step {e.get('step')}: {e.get('value')}", file=out)
    return 1 if viols else 0


def validate(path: str, out=sys.stdout) -> int:
    n_ok, errors = schema.validate_file(path)
    print(f"{path}: {n_ok} valid records, {len(errors)} errors", file=out)
    for lineno, err in errors[:20]:
        print(f"  line {lineno}: {err}", file=out)
    return 1 if errors else 0


# ---------------------------------------------------------------------------
# diff
# ---------------------------------------------------------------------------
def _row_key(rec: dict) -> Tuple:
    if rec.get("kind") == "breakdown":
        return ("breakdown", rec.get("path"), rec.get("component"),
                rec.get("nl"))
    return (rec.get("name"), rec.get("nl"), rec.get("nt"))


def diff_records(old: List[dict], new: List[dict]) -> List[dict]:
    """Match rows by identity, compute time ratio new/old (>1 = slower)."""
    old_by = {_row_key(r): r for r in old}
    rows = []
    for r in new:
        k = _row_key(r)
        o = old_by.get(k)
        if o is None or not o.get("us_per_call") or not r.get("us_per_call"):
            continue
        rows.append(dict(
            key="/".join(str(x) for x in k if x is not None),
            old_us=o["us_per_call"], new_us=r["us_per_call"],
            ratio=r["us_per_call"] / o["us_per_call"]))
    return rows


def diff(old_path: str, new_path: str, threshold: float = 0.10,
         fail: bool = False, out=sys.stdout) -> int:
    with open(old_path) as fh:
        old = json.load(fh)
    with open(new_path) as fh:
        new = json.load(fh)
    rows = diff_records(old, new)
    if not rows:
        print("no matching rows", file=out)
        return 2
    print(f"# {new_path} vs {old_path} ({len(rows)} matched rows)", file=out)
    print(f"{'row':<48} {'old_us':>10} {'new_us':>10} {'ratio':>7}",
          file=out)
    regressions = []
    for r in sorted(rows, key=lambda r: -r["ratio"]):
        mark = ""
        if r["ratio"] > 1.0 + threshold:
            mark = "  <-- slower"
            regressions.append(r)
        elif r["ratio"] < 1.0 - threshold:
            mark = "  (faster)"
        print(f"{r['key']:<48} {r['old_us']:>10.1f} {r['new_us']:>10.1f} "
              f"{r['ratio']:>6.2f}x{mark}", file=out)
    if regressions:
        print(f"\n{len(regressions)} row(s) regressed beyond "
              f"{threshold:.0%}", file=out)
        if fail:
            return 1
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="obs_report")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summary", help="render a metrics JSONL stream")
    ps.add_argument("path")
    pv = sub.add_parser("validate", help="schema-check a metrics JSONL")
    pv.add_argument("path")
    pd = sub.add_parser("diff", help="diff two BENCH_*.json artifacts")
    pd.add_argument("old")
    pd.add_argument("new")
    pd.add_argument("--threshold", type=float, default=0.10,
                    help="relative slowdown that counts as a regression")
    pd.add_argument("--fail", action="store_true",
                    help="exit 1 if any row regresses beyond threshold")
    args = ap.parse_args(argv)
    if args.cmd == "summary":
        return summary(args.path)
    if args.cmd == "validate":
        return validate(args.path)
    return diff(args.old, args.new, threshold=args.threshold, fail=args.fail)


if __name__ == "__main__":
    sys.exit(main())
