"""Paper Fig. 14 / §4.1: per-kernel utilisation.

For each Pallas kernel (ref path on CPU): measured CPU wall time, the
bytes/flops it moves, and the *modelled* TPU v5e roofline fraction
(arithmetic intensity vs the 240 FLOP/byte ridge).  The paper reports
80 % of peak BW for memory-bound kernels and ~60 % of peak compute for
compute-bound ones; the kernels' modelled positions on the roofline are the
TPU-side expectation (validated in interpret mode for correctness).

Also measures the SoA<->cell transpose (paper: "nearly achieves peak memory
bandwidth") and the fused-2D-mode dispatch-latency experiment (paper §3.3 /
beyond-paper opt #1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dg2d, geometry, mesh2d
from repro.kernels import ref as kref
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS_BF16

from .common import row, time_fn


def run():
    rng = np.random.default_rng(0)
    nl, C = 32, 128 * 64            # 8192 columns

    # tridiagonal solve: 8 reads+writes per row -> memory bound
    dl, du = [jnp.asarray(rng.normal(size=(nl, C)).astype(np.float32)) * 0.3
              for _ in range(2)]
    d = 2.0 + jnp.abs(jnp.asarray(rng.normal(size=(nl, C)).astype(np.float32)))
    bb = jnp.asarray(rng.normal(size=(nl, C)).astype(np.float32))
    f = jax.jit(kref.tridiag)
    t = time_fn(f, dl, d, du, bb)
    bytes_ = 6 * nl * C * 4
    row("kernel_tridiag", t * 1e6,
        f"cpu_GBps={bytes_ / t / 1e9:.2f};"
        f"tpu_roofline=memory;ai={8 * nl * C / bytes_:.2f}")

    # matrix-free r solve
    F = jnp.asarray(rng.normal(size=(nl * 6, C)).astype(np.float32))
    area = jnp.abs(jnp.asarray(rng.normal(size=(1, C)).astype(np.float32))) + .5
    rs = jnp.asarray(rng.normal(size=(3, C)).astype(np.float32))
    f = jax.jit(kref.solve_r_cell)
    t = time_fn(f, F, area, rs)
    bytes_ = 2 * nl * 6 * C * 4
    row("kernel_matrix_free_r", t * 1e6,
        f"cpu_GBps={bytes_ / t / 1e9:.2f};tpu_roofline=memory")

    # block-tridiagonal solve: ~6^3*2*nl flops/col vs 3*36*nl*4 bytes/col
    mk = lambda: jnp.asarray(0.1 * rng.normal(size=(nl, 6, 6, C))
                             ).astype(jnp.float32)
    lo = mk().at[0].set(0.0)
    up = mk().at[-1].set(0.0)
    dg = mk() + 2.0 * jnp.eye(6)[None, :, :, None]
    b2 = jnp.asarray(rng.normal(size=(nl, 6, 2, C)).astype(np.float32))
    f = jax.jit(kref.block_thomas_cell)
    t = time_fn(f, lo, dg, up, b2)
    flops = 2 * (6 ** 3) * 2 * nl * C
    bytes_ = (3 * 36 + 12 * 2) * nl * C * 4
    ai = flops / bytes_
    ridge = PEAK_FLOPS_BF16 / HBM_BW
    bound = "compute" if ai > ridge else "memory"
    row("kernel_block_thomas", t * 1e6,
        f"cpu_GFLOPs={flops / t / 1e9:.1f};ai={ai:.1f};tpu_roofline={bound}")

    # SoA<->cell transpose: pure streaming copy
    x = jnp.asarray(rng.normal(size=(nl, 6, C)).astype(np.float32))
    f = jax.jit(lambda x: kref.soa_to_cell(x))
    t = time_fn(f, x)
    bytes_ = 2 * nl * 6 * C * 4
    row("kernel_cell_transpose", t * 1e6,
        f"cpu_GBps={bytes_ / t / 1e9:.2f};"
        f"tpu_expectation=peak_bw (paper §2.1.2)")

    # 2D-mode dispatch latency: fused m-substep scan vs per-substep calls
    m = mesh2d.rect_mesh(12, 10, 5e3, 4e3, jitter=0.15, seed=4)
    geom = geometry.geom2d_from_mesh(m)
    b3 = jnp.full((3, m.nt), 20.0)
    st = dg2d.State2D(*[jnp.zeros((3, m.nt))] * 3)
    msteps = 20
    dt = dg2d.cfl_dt(geom, b3) * msteps

    fused = jax.jit(lambda s: dg2d.run_external(geom, b3, s, dt, msteps))
    t_fused = time_fn(fused, st)
    single = jax.jit(lambda s: dg2d.ssprk3_step(
        lambda x: dg2d.external_rhs(geom, b3, x), s, dt / msteps))

    def unfused(s):
        for _ in range(msteps):
            s = single(s)
        return s
    t_unfused = time_fn(unfused, st)
    row("fused_2d_burst_vs_calls", t_fused * 1e6,
        f"unfused_us={t_unfused * 1e6:.1f};"
        f"fusion_speedup={t_unfused / t_fused:.2f} (paper §3.3 latency wall)")


if __name__ == "__main__":
    run()
