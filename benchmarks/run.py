"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper-figure analogues measured
on CPU + TPU roofline models; see each module's docstring for the mapping).

  bench_column_solve — paper Fig. 15 axis: ref vs Pallas cell-layout column
                     solvers (block-Thomas + matrix-free r/w) over nl/columns
  bench_horizontal_rhs — the fused horizontal-RHS pipeline vs the seed
                     per-call path vs the Pallas lateral-flux kernel over
                     nl in {4,8,16}; also writes BENCH_horizontal.json
                     (machine-readable perf trajectory of the hottest loop)
  fig13_resolution — paper Fig. 13 (perf vs horizontal resolution)
  fig15_layers     — paper Fig. 15 (layer-count scaling / occupancy)
  fig16_scaling    — paper Figs. 16-18 (multi-device scaling, Amdahl)
  kernel_util      — paper Fig. 14 / §4.1 (per-kernel utilisation) + the
                     §3.3 dispatch-latency experiment
  roofline_table   — the 40-cell dry-run roofline table (EXPERIMENTS.md)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip the multi-process scaling benchmark")
    args = ap.parse_args()

    from . import (bench_column_solve, bench_horizontal_rhs, fig13_resolution,
                   fig15_layers, fig16_scaling, kernel_util, roofline_table)
    benches = {
        "kernel_util": kernel_util.run,
        "bench_column_solve": bench_column_solve.run,
        "bench_horizontal_rhs": bench_horizontal_rhs.run,
        "fig13_resolution": fig13_resolution.run,
        "fig15_layers": fig15_layers.run,
        "fig16_scaling": fig16_scaling.run,
        "roofline_table": roofline_table.run,
    }
    if args.only:
        names = args.only.split(",")
    else:
        names = list(benches)
        if args.skip_slow:
            names.remove("fig16_scaling")
    print("name,us_per_call,derived")
    ok = True
    for n in names:
        try:
            benches[n]()
        except Exception:
            traceback.print_exc()
            print(f"{n},0,FAILED")
            ok = False
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
