"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn, *args, warmup=2, iters=5, reduce="median", **kw):
    """Wall time of fn(*args) with block_until_ready, in seconds.

    reduce: "median" (default) or "min" — min is the robust choice on noisy
    shared machines (any sample is an upper bound on the true cost)."""
    if reduce not in ("median", "min"):
        raise ValueError(f"reduce must be 'median' or 'min', got {reduce!r}")
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(min(ts) if reduce == "min" else np.median(ts))


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
