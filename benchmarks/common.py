"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
