"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def block_tree(out):
    """Block until every array leaf of an arbitrary pytree is ready.

    `jax.block_until_ready` handles pytrees too, but walking the leaves and
    skipping non-blockable ones (python scalars, None, strings in result
    dicts) keeps this robust for any benchmark return value."""
    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


class Timing(float):
    """A float (the `reduce` statistic, seconds) carrying the full sample
    spread as attributes — existing callers keep doing float arithmetic,
    artifact writers pick up p50/p90."""
    p50: float
    p90: float
    min: float
    mean: float
    n: int

    def __new__(cls, primary, samples):
        self = super().__new__(cls, primary)
        s = np.sort(np.asarray(samples, dtype=float))
        self.p50 = float(np.percentile(s, 50))
        self.p90 = float(np.percentile(s, 90))
        self.min = float(s[0])
        self.mean = float(s.mean())
        self.n = int(s.size)
        return self

    def stats(self) -> dict:
        """Plain-dict form for JSON artifact rows (values in seconds)."""
        return {"p50": self.p50, "p90": self.p90, "min": self.min,
                "mean": self.mean, "n": self.n}


def time_fn(fn, *args, warmup=2, iters=5, reduce="median", **kw):
    """Wall time of fn(*args) with full-pytree block_until_ready, in seconds.

    Returns a `Timing` (a float subclass): the value is the `reduce`
    statistic, and .p50/.p90/.min/.mean/.n carry the sample spread.

    reduce: "median" (default) or "min" — min is the robust choice on noisy
    shared machines (any sample is an upper bound on the true cost)."""
    if reduce not in ("median", "min"):
        raise ValueError(f"reduce must be 'median' or 'min', got {reduce!r}")
    for _ in range(warmup):
        block_tree(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_tree(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    primary = float(min(ts) if reduce == "min" else np.median(ts))
    return Timing(primary, ts)


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
